"""Model substrate numerics: attention equivalences, SSD duality,
decode/forward consistency, MoE dispatch equivalence, M-RoPE."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import (decode_step, forward, init_params, loss_fn,
                          make_cache, prefill)
from repro.models.attention import attend
from repro.models.config import ModelConfig
from repro.models.moe import moe_block, moe_block_capacity, moe_params
from repro.models.ssm import ssd_chunked, ssd_recurrent_step

KEY = jax.random.PRNGKey(0)

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab=97, q_chunk=8)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 9])
def test_chunked_equals_naive_attention(causal, window):
    B, S, H, KV, hd = 2, 37, 8, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    a = attend(q, k, v, pos, pos, causal=causal, window=window, scale=0.25,
               q_chunk=8, impl="chunked")
    b = attend(q, k, v, pos, pos, causal=causal, window=window, scale=0.25,
               q_chunk=8, impl="naive")
    np.testing.assert_allclose(a, b, atol=1e-5)


@pytest.mark.parametrize("chunk", [5, 8, 25])
def test_ssd_chunked_equals_recurrence(chunk):
    Bt, S, H, P, G, N = 2, 25, 4, 8, 2, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bt, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bv = jax.random.normal(ks[3], (Bt, S, G, N))
    Cv = jax.random.normal(ks[4], (Bt, S, G, N))
    y_c, hf = ssd_chunked(x, dt, A, Bv, Cv, chunk=chunk)
    h = jnp.zeros((Bt, H, P, N))
    ys = []
    for t in range(S):
        y_t, h = ssd_recurrent_step(x[:, t], dt[:, t], A, Bv[:, t], Cv[:, t], h)
        ys.append(y_t)
    np.testing.assert_allclose(y_c, jnp.stack(ys, 1), atol=3e-4)
    np.testing.assert_allclose(hf, h, atol=3e-4)


def _decode_matches_forward(cfg, atol=3e-3, steps=10):
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (1, steps), 0, cfg.vocab)
    logits_full, _ = forward(params, cfg, {"tokens": toks})
    cache = make_cache(cfg, 1, steps)
    for t in range(steps):
        lg, cache = decode_step(params, cfg, cache, toks[:, t:t + 1],
                                jnp.int32(t))
        err = float(jnp.abs(lg[0, 0] - logits_full[0, t]).max())
        assert err < atol, (cfg.name, t, err)


def test_decode_matches_forward_gqa():
    _decode_matches_forward(ModelConfig(name="d", **BASE))


def test_decode_matches_forward_mla_absorbed():
    _decode_matches_forward(ModelConfig(
        name="m", use_mla=True, kv_lora_rank=32, q_lora_rank=48,
        rope_head_dim=8, nope_head_dim=16, v_head_dim=16, **BASE))


def test_decode_matches_forward_ssm():
    _decode_matches_forward(ModelConfig(
        name="s", family="ssm", n_layers=2, d_model=64, vocab=97,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8, d_ff=0, rope="none"))


def test_decode_matches_forward_hybrid():
    _decode_matches_forward(ModelConfig(
        name="h", family="hybrid", n_layers=4, attn_every=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=97,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8, q_chunk=8))


def test_sliding_window_ring_decode_matches_plain():
    """Ring cache with window W == plain cache decode with window W."""
    cfg = ModelConfig(name="w", **BASE)
    params = init_params(cfg, KEY)
    S = 24
    W = 8
    toks = jax.random.randint(KEY, (1, S), 0, cfg.vocab)
    plain = make_cache(cfg, 1, S)
    ring = make_cache(cfg, 1, W, ring=True)
    for t in range(S):
        lg_p, plain = decode_step(params, cfg, plain, toks[:, t:t + 1],
                                  jnp.int32(t), window=W)
        lg_r, ring = decode_step(params, cfg, ring, toks[:, t:t + 1],
                                 jnp.int32(t), window=W, ring=True)
        np.testing.assert_allclose(lg_p, lg_r, atol=2e-3)


def test_moe_capacity_matches_dense_when_no_drop():
    cfg = ModelConfig(name="moe", family="moe", n_experts=4, top_k=2,
                      moe_ff=32, shared_ff=32, **BASE)
    p = moe_params(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y_d, aux_d = moe_block(p, x, cfg)
    y_c, aux_c = moe_block_capacity(p, x, cfg, capacity_factor=4.0)
    np.testing.assert_allclose(y_d, y_c, atol=2e-4)
    np.testing.assert_allclose(aux_d, aux_c, atol=1e-5)


def test_moe_aux_loss_minimum_is_topk():
    """Load-balance loss: balanced routing gives aux == k (its minimum for
    top-k); concentrating probability on the chosen experts raises it."""
    from repro.models.moe import router_topk
    _, aux_bal, _ = router_topk(jnp.zeros((64, 4)), 2)
    assert 1.95 < float(aux_bal) < 2.05
    skew = jnp.tile(jnp.array([[8.0, 8.0, -8.0, -8.0]]), (64, 1))
    _, aux_skew, _ = router_topk(skew, 2)
    assert float(aux_skew) > float(aux_bal) + 1.5


def test_mrope_reduces_to_rope_for_text():
    """With all three position streams equal, M-RoPE == standard RoPE."""
    from repro.models.layers import mrope_angles, rope_angles
    pos = jnp.arange(10, dtype=jnp.int32)[None]  # (1, 10)
    pos3 = jnp.broadcast_to(pos[None], (3, 1, 10))
    c1, s1 = rope_angles(pos, 8, 10000.0)
    c3, s3 = mrope_angles(pos3, (4, 2, 2), 10000.0)
    np.testing.assert_allclose(c1, c3, atol=1e-6)
    np.testing.assert_allclose(s1, s3, atol=1e-6)


def test_encoder_has_no_decode():
    cfg = ModelConfig(name="enc", family="audio", embed_inputs=True,
                      causal=False, has_decode=False, **BASE)
    params = init_params(cfg, KEY)
    with pytest.raises(ValueError):
        decode_step(params, cfg, None, jnp.zeros((1, 1), jnp.int32),
                    jnp.int32(0))


def test_pallas_attention_impl_in_model():
    """attention_impl='pallas' (interpret) == 'naive' end to end."""
    cfg_n = ModelConfig(name="n", attention_impl="naive", **BASE)
    cfg_p = ModelConfig(name="p", attention_impl="pallas", **BASE)
    params = init_params(cfg_n, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg_n.vocab)
    ln, _ = forward(params, cfg_n, {"tokens": toks})
    lp, _ = forward(params, cfg_p, {"tokens": toks})
    np.testing.assert_allclose(ln, lp, atol=2e-3)
