"""The fused Pallas engine (``engine='fused'``) vs the XLA scan engine.

The contract under test (ROADMAP item 3 / ``repro.kernels.fused_step``):
``ExecutionSpec(engine='fused')`` routes the per-event policy update
(window-sum / select / circular push) plus the iterate step through ONE
Pallas kernel per event, and every solver row is BITWISE-equal to the
default ``engine='scan'`` path on every backend.  Both engines run jitted
(the production paths always are); eager references would differ by FMA
contraction and are deliberately absent here.

Also pins the two bugfix satellites that ride along:
* ``StepsizePolicy.run`` sizes its window buffer from the trace's own
  largest delay and warns loudly when delays exceed the available history
  (silent-clipping regression -- fails on the pre-fix sizing
  ``min(DEFAULT_HORIZON, len(taus))``);
* the fused engine refuses ``AdaptiveLipschitz`` loudly (backtracking is
  host-side; no silent fallback).
"""
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.core.problems import make_logreg
from repro.core.prox import make_prox
from repro.core.stepsize import (Adaptive1, AdaptiveLipschitz, auto_horizon,
                                 make_policy)
from repro.federated.events import heterogeneous_clients
from repro.kernels.fused_step import as_policy_params, fused_policy_prox_step
from repro.sweep.grid import make_grid, standard_topology_factories
from repro.sweep.policies import policy_params

N_EVENTS = 64
FED_EVENTS = 48


@pytest.fixture(scope="module")
def problem():
    return make_logreg(n_samples=200, dim=30, n_workers=8, seed=0)


@pytest.fixture(scope="module")
def prox(problem):
    return make_prox("l1", lam=problem.lam1)


@pytest.fixture(scope="module")
def worker_grid(problem):
    gp = 0.99 / problem.L
    policies = {
        n: make_policy(n, gp, **({"tau_bound": 64} if n == "fixed" else {}))
        for n in ("adaptive1", "adaptive2", "fixed", "naive")}
    topos = {"uniform": standard_topology_factories(0)["uniform"]}
    return make_grid(policies, [0, 1], topos, N_EVENTS, n_workers=[8])


@pytest.fixture(scope="module")
def fed_grid():
    policies = {n: make_policy(n, 0.6)
                for n in ("adaptive1", "adaptive2", "naive")}
    topos = {"edge": lambda n: heterogeneous_clients(n, seed=0)}
    return make_grid(policies, [0, 1], topos, FED_EVENTS, n_workers=[8])


def _solver_kwargs(solver):
    return {"bcd": {"m": 4}, "fedbuff": {"eta": 0.5, "buffer_size": 2}}.get(
        solver, {})


def _raw(solver, backend, engine, problem, prox, grid):
    return api.run_components(solver, backend, problem=problem, grid=grid,
                              prox=prox, engine=engine,
                              **_solver_kwargs(solver)).raw


def _assert_bitwise(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("backend", ["batched", "sharded", "solo"])
@pytest.mark.parametrize("solver", ["piag", "bcd", "fedasync", "fedbuff"])
def test_fused_engine_bitwise(solver, backend, problem, prox, worker_grid,
                              fed_grid):
    """engine='fused' == engine='scan' on every leaf, 4 solvers x 3
    backends -- the tentpole equivalence grid."""
    grid = fed_grid if solver.startswith("fed") else worker_grid
    scan = _raw(solver, backend, "scan", problem, prox, grid)
    fused = _raw(solver, backend, "fused", problem, prox, grid)
    _assert_bitwise(scan, fused)


def test_fused_engine_telemetry_neutral(problem, prox, worker_grid):
    """Telemetry accumulators in the carry never perturb the fused solver
    leaves, and the aggregates match the scan engine's exactly."""
    plain = api.run_components("piag", "batched", problem=problem,
                               grid=worker_grid, prox=prox, engine="fused")
    with_tel = api.run_components("piag", "batched", problem=problem,
                                  grid=worker_grid, prox=prox, engine="fused",
                                  telemetry=True)
    scan_tel = api.run_components("piag", "batched", problem=problem,
                                  grid=worker_grid, prox=prox, engine="scan",
                                  telemetry=True)
    for field in ("x", "objective", "gammas", "taus"):
        np.testing.assert_array_equal(
            np.asarray(getattr(plain.raw, field)),
            np.asarray(getattr(with_tel.raw, field)))
    _assert_bitwise(with_tel.raw.telemetry, scan_tel.raw.telemetry)


def test_engine_validation():
    with pytest.raises(ValueError, match="engine"):
        api.ExecutionSpec(engine="bogus")
    from repro.core.piag import piag_scan
    with pytest.raises(ValueError, match="engine"):
        piag_scan(lambda x, A, b: 0.0, jnp.zeros(3), (None, None),
                  (jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32)),
                  make_policy("naive", 0.1), make_prox("none"),
                  engine="vectorized")


def test_fused_rejects_adaptive_lipschitz(problem, prox, worker_grid):
    """The backtracking policy cannot flatten to PolicyParams; the fused
    engine must fail loudly, never fall back silently."""
    with pytest.raises(TypeError):
        as_policy_params(AdaptiveLipschitz(gamma_prime=0.1))
    with pytest.raises(TypeError):
        policy_params(AdaptiveLipschitz(gamma_prime=0.1))


@pytest.mark.parametrize("name", ["adaptive1", "adaptive2", "fixed", "naive",
                                  "hinge", "poly"])
def test_fused_kernel_matches_policy_step(name):
    """Kernel-level pin: one fused step == the jitted policy.step + prox
    composition, per policy family (both sides jitted -- XLA contracts
    mul+sub to FMA under jit, so an eager reference would be 1 ulp off)."""
    policy = make_policy(name, 0.3, **({"tau_bound": 7} if name == "fixed"
                                       else {}))
    params = policy_params(policy)
    prox = make_prox("l1", lam=0.05)
    horizon = 16
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (33,))
    g = jax.random.normal(jax.random.PRNGKey(4), (33,))
    taus = jnp.asarray([0, 1, 3, 7, 2], jnp.int32)

    @jax.jit
    def run_scan(x, g):
        def body(carry, tau):
            x, ss = carry
            gamma, ss = policy.step(ss, tau)
            return (prox.prox(x - gamma * g, gamma), ss), gamma
        (xf, _), gs = jax.lax.scan(body, (x, policy.init(horizon)), taus)
        return xf, gs

    @jax.jit
    def run_fused(x, g):
        def body(carry, tau):
            x, ss = carry
            gamma, ss, x = fused_policy_prox_step(params, prox, ss, tau, x, g)
            return (x, ss), gamma
        (xf, _), gs = jax.lax.scan(body, (x, policy.init(horizon)), taus)
        return xf, gs

    xa, ga = run_scan(x, g)
    xb, gb = run_fused(x, g)
    np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(gb))


# ------------------------- satellite: loud horizon sizing in policy.run ----

def test_run_warns_on_delay_beyond_history():
    """REGRESSION (silent-clipping bugfix): a short trace carrying a delay
    larger than the available history must warn -- the pre-fix sizing
    ``min(DEFAULT_HORIZON, len(taus))`` clipped it silently."""
    taus = jnp.asarray([0, 1, 2, 3, 64, 0, 1, 2, 3, 4], jnp.int32)
    with pytest.warns(RuntimeWarning, match="delay exceeding"):
        gammas = Adaptive1(gamma_prime=0.3).run(taus)
    assert gammas.shape == (10,)
    assert bool(jnp.all(jnp.isfinite(gammas)))


def test_run_sizes_buffer_from_max_tau():
    """The buffer is sized from max(taus), not len(taus): the emitted
    sequence is bitwise what an explicitly oversized scan produces."""
    policy = Adaptive1(gamma_prime=0.3)
    taus = jnp.asarray([0, 1, 2, 3, 64, 0, 1, 2, 3, 4], jnp.int32)

    @jax.jit
    def big_horizon(taus):
        def body(ss, tau):
            g, ss = policy.step(ss, tau)
            return ss, g
        return jax.lax.scan(body, policy.init(8192), taus)[1]

    with pytest.warns(RuntimeWarning):  # tau=64 > k=4 still exceeds history
        got = policy.run(taus)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(big_horizon(taus)))
    assert auto_horizon(64) >= 65  # the sizing rule the fix installs


def test_run_silent_for_windowless_policies():
    """Policies that never consume the window (fixed/naive families) must
    not warn on large delays -- the clip is diagnostic-only for them."""
    taus = jnp.asarray([0, 300, 1, 2], jnp.int32)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        make_policy("naive", 0.3).run(taus)
        make_policy("fixed", 0.3, tau_bound=300).run(taus)
