import sys
import types

import pytest

try:
    import hypothesis  # noqa: F401  (real package, if available)
except ImportError:
    # Offline container: install the deterministic stub (tests/_hypothesis_stub)
    # under the `hypothesis` name before test modules import it.
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).parent))
    import _hypothesis_stub as _stub

    mod = types.ModuleType("hypothesis")
    mod.given = _stub.given
    mod.settings = _stub.settings
    mod.assume = _stub.assume
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from"):
        setattr(st_mod, name, getattr(_stub.strategies, name))
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
    # informational when pytest-timeout is absent (offline container); the
    # chaos tests ALSO assert wall-clock bounds themselves, and the CI
    # chaos lane wraps the whole invocation in a shell-level timeout
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test time budget "
        "(enforced by pytest-timeout when installed)")


def pytest_addoption(parser):
    parser.addoption("--skip-slow", action="store_true", default=False)


def pytest_collection_modifyitems(config, items):
    if config.getoption("--skip-slow"):
        skip = pytest.mark.skip(reason="--skip-slow")
        for item in items:
            if "slow" in item.keywords:
                item.add_marker(skip)
