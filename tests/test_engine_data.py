"""Event-engine and data-pipeline properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DelayTracker, EventHeap, WorkerModel,
                        heterogeneous_workers, simulate_parameter_server,
                        simulate_shared_memory)
from repro.data import EmbedStream, TokenStream


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 12))
def test_parameter_server_trace_invariants(seed, n):
    tr = simulate_parameter_server(n, 300, seed=seed)
    # wall-clock monotone non-decreasing (events are completions in order)
    assert np.all(np.diff(tr.t_wall) >= 0)
    # delays are write-event counts: 0 <= tau <= tau_max <= k
    k = np.arange(300)
    assert np.all(tr.tau >= 0) and np.all(tr.tau <= k)
    assert np.all(tr.tau_max >= tr.tau) and np.all(tr.tau_max <= k)
    # a worker's reads are strictly increasing (it always picks up the
    # newest iterate after its own write)
    for w in range(n):
        mine = tr.read_at[tr.worker == w]
        assert np.all(np.diff(mine) > 0)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_shared_memory_trace_invariants(seed):
    tr = simulate_shared_memory(4, 200, 10, seed=seed)
    assert np.all(tr.tau >= 0)
    assert np.all(np.diff(tr.t_wall) >= 0)


def test_straggler_model_increases_delays():
    fast = simulate_parameter_server(
        6, 2000, [WorkerModel(mean=1.0)] * 6, seed=0)
    slow = simulate_parameter_server(
        6, 2000, [WorkerModel(mean=1.0, p_straggle=0.3, straggle_x=20)] * 6,
        seed=0)
    assert slow.max_delay() > fast.max_delay()


def test_heterogeneous_workers_speed_spread():
    ws = heterogeneous_workers(8, spread=3.0, seed=1)
    means = sorted(w.mean for w in ws)
    assert means[0] == pytest.approx(1.0)
    assert means[-1] == pytest.approx(3.0)


def test_event_heap_ties_pop_in_push_order():
    """Regression: simultaneous completions must pop deterministically by
    (time, seq) -- insertion order wins among equal times.  Without the seq
    tiebreak, heapq would fall through to comparing payloads (worker ids,
    arbitrary objects), making trace order depend on payload values."""
    h = EventHeap()
    h.push(2.0, "late-a")
    h.push(1.0, "tied-1")
    h.push(1.0, "tied-2")
    h.push(1.0, "tied-3")
    h.push(0.5, "early")
    order = [h.pop()[1] for _ in range(len(h))]
    assert order == ["early", "tied-1", "tied-2", "tied-3", "late-a"]


def test_event_heap_ties_tolerate_uncomparable_payloads():
    """The seq tiebreak must shield payloads from comparison entirely --
    dict payloads would raise TypeError if heapq ever reached them."""
    h = EventHeap()
    h.push(1.0, {"a": 1})
    h.push(1.0, {"b": 2})
    assert h.pop()[1] == {"a": 1}
    assert h.pop()[1] == {"b": 2}


def test_simultaneous_arrivals_trace_is_round_robin():
    """Deterministic identical service times tie every completion; the
    pinned order is round-robin in worker index (= push order), for both
    the heap reference and the jitted generator -- see test_sweep.py for
    the scan side."""
    workers = [WorkerModel(sigma=0.0) for _ in range(3)]
    from repro.core import sample_service_times
    T = sample_service_times(workers, 10, seed=0)
    tr = simulate_parameter_server(3, 9, workers, seed=0, service_times=T)
    np.testing.assert_array_equal(tr.worker, np.tile(np.arange(3), 3))
    np.testing.assert_array_equal(tr.t_wall, np.repeat([1.0, 2.0, 3.0], 3))


def test_presampled_service_times_reproduce_event_structure():
    """The service_times path is a drop-in replacement for on-the-fly
    sampling: same invariants, and worker i's k-th task duration is exactly
    T[i, k] (wall-clock of a worker's completions telescopes the matrix)."""
    workers = heterogeneous_workers(4, seed=9)
    from repro.core import sample_service_times
    T = sample_service_times(workers, 201, seed=9)
    tr = simulate_parameter_server(4, 200, workers, seed=0, service_times=T)
    assert np.all(np.diff(tr.t_wall) >= 0)
    for w in range(4):
        mine = tr.t_wall[tr.worker == w]
        # completion times of worker w are prefix sums of row w (f32)
        pref = np.cumsum(T[w].astype(np.float32))[:len(mine)]
        np.testing.assert_allclose(mine, pref, rtol=1e-6)


def test_delay_tracker_unstamped_worker_raises():
    """Regression: an unstamped worker used to silently default to stamp 0,
    reporting staleness k -- indistinguishable from a real straggler and
    enough to crush any delay-adaptive step-size to zero."""
    tr = DelayTracker()
    tr.stamp(0, 0)
    for _ in range(5):
        tr.advance()
    assert tr.delay(0) == 5
    with pytest.raises(KeyError):
        tr.delay(1)          # never stamped -> loud failure, not tau = k
    assert 1 not in tr.delays()
    tr.stamp(1)              # explicit stamp at the current version
    assert tr.delay(1) == 0


def test_token_stream_batches_independent_of_order():
    ts = TokenStream(vocab=128, batch=2, seq=16, seed=3)
    a = np.asarray(ts.batch_at(7)["tokens"])
    _ = ts.batch_at(3)
    b = np.asarray(ts.batch_at(7)["tokens"])
    np.testing.assert_array_equal(a, b)


def test_embed_stream_deterministic():
    es = EmbedStream(d_model=16, vocab=8, batch=2, seq=10, seed=0)
    np.testing.assert_allclose(es.batch_at(4)["embeds"],
                               es.batch_at(4)["embeds"])
