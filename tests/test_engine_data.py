"""Event-engine and data-pipeline properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DelayTracker, WorkerModel, heterogeneous_workers,
                        simulate_parameter_server, simulate_shared_memory)
from repro.data import EmbedStream, TokenStream


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 12))
def test_parameter_server_trace_invariants(seed, n):
    tr = simulate_parameter_server(n, 300, seed=seed)
    # wall-clock monotone non-decreasing (events are completions in order)
    assert np.all(np.diff(tr.t_wall) >= 0)
    # delays are write-event counts: 0 <= tau <= tau_max <= k
    k = np.arange(300)
    assert np.all(tr.tau >= 0) and np.all(tr.tau <= k)
    assert np.all(tr.tau_max >= tr.tau) and np.all(tr.tau_max <= k)
    # a worker's reads are strictly increasing (it always picks up the
    # newest iterate after its own write)
    for w in range(n):
        mine = tr.read_at[tr.worker == w]
        assert np.all(np.diff(mine) > 0)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_shared_memory_trace_invariants(seed):
    tr = simulate_shared_memory(4, 200, 10, seed=seed)
    assert np.all(tr.tau >= 0)
    assert np.all(np.diff(tr.t_wall) >= 0)


def test_straggler_model_increases_delays():
    fast = simulate_parameter_server(
        6, 2000, [WorkerModel(mean=1.0)] * 6, seed=0)
    slow = simulate_parameter_server(
        6, 2000, [WorkerModel(mean=1.0, p_straggle=0.3, straggle_x=20)] * 6,
        seed=0)
    assert slow.max_delay() > fast.max_delay()


def test_heterogeneous_workers_speed_spread():
    ws = heterogeneous_workers(8, spread=3.0, seed=1)
    means = sorted(w.mean for w in ws)
    assert means[0] == pytest.approx(1.0)
    assert means[-1] == pytest.approx(3.0)


def test_delay_tracker_unstamped_worker_raises():
    """Regression: an unstamped worker used to silently default to stamp 0,
    reporting staleness k -- indistinguishable from a real straggler and
    enough to crush any delay-adaptive step-size to zero."""
    tr = DelayTracker()
    tr.stamp(0, 0)
    for _ in range(5):
        tr.advance()
    assert tr.delay(0) == 5
    with pytest.raises(KeyError):
        tr.delay(1)          # never stamped -> loud failure, not tau = k
    assert 1 not in tr.delays()
    tr.stamp(1)              # explicit stamp at the current version
    assert tr.delay(1) == 0


def test_token_stream_batches_independent_of_order():
    ts = TokenStream(vocab=128, batch=2, seq=16, seed=3)
    a = np.asarray(ts.batch_at(7)["tokens"])
    _ = ts.batch_at(3)
    b = np.asarray(ts.batch_at(7)["tokens"])
    np.testing.assert_array_equal(a, b)


def test_embed_stream_deterministic():
    es = EmbedStream(d_model=16, vocab=8, batch=2, seq=10, seed=0)
    np.testing.assert_allclose(es.batch_at(4)["embeds"],
                               es.batch_at(4)["embeds"])
