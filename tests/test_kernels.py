"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("shape", [(17,), (1000,), (64, 130), (3, 5, 7),
                                   (2048,)])
@pytest.mark.parametrize("kind", ["none", "l1", "l2", "box"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prox_step_sweep(shape, kind, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), shape, dtype)
    got = ops.prox_step(x, g, 0.13, kind=kind, lam=0.05)
    want = ref.prox_step_ref(x, g, jnp.float32(0.13), kind=kind, lam=0.05)
    atol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)
    assert got.dtype == dtype


@pytest.mark.parametrize("dims", [(2, 33, 33, 16), (1, 128, 128, 32),
                                  (3, 65, 200, 64), (2, 1, 96, 16)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 13),
                                           (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(dims, causal, window, dtype):
    BH, Sq, Sk, d = dims
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (BH, Sq, d), dtype)
    k = jax.random.normal(ks[1], (BH, Sk, d), dtype)
    v = jax.random.normal(ks[2], (BH, Sk, d), dtype)
    qp = jnp.arange(Sq, dtype=jnp.int32) + (Sk - Sq)
    kp = jnp.arange(Sk, dtype=jnp.int32)
    got = flash_attention_bhsd(q, k, v, qp, kp, causal=causal, window=window,
                               scale=d ** -0.5, block_q=32, block_k=64)
    want = ref.flash_attention_ref(q, k, v, qp, kp, causal=causal,
                                   window=window, scale=d ** -0.5)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_attention_ring_holes():
    """kpos == -1 slots (ring-cache holes) are ignored."""
    BH, Sq, Sk, d = 2, 4, 32, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (BH, Sq, d))
    k = jax.random.normal(ks[1], (BH, Sk, d))
    v = jax.random.normal(ks[2], (BH, Sk, d))
    qp = jnp.arange(Sq, dtype=jnp.int32) + 100
    kp = jnp.where(jnp.arange(Sk) % 3 == 0, -1,
                   jnp.arange(Sk, dtype=jnp.int32) + 90)
    got = flash_attention_bhsd(q, k, v, qp, kp, causal=True, window=None,
                               scale=0.25, block_q=4, block_k=8)
    want = ref.flash_attention_ref(q, k, v, qp, kp, causal=True, window=None,
                                   scale=0.25)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("gqa", [(8, 2), (4, 4), (6, 1)])
def test_flash_gqa_fold_vs_model_attend(gqa):
    from repro.models.attention import attend
    H, KV = gqa
    B, S, d = 2, 45, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, KV, d))
    v = jax.random.normal(ks[2], (B, S, KV, d))
    pos = jnp.arange(S, dtype=jnp.int32)
    got = ops.flash_attention(q, k, v, pos, pos, causal=True, window=None,
                              scale=0.25)
    want = attend(q, k, v, pos, pos, causal=True, window=None, scale=0.25,
                  q_chunk=16, impl="naive")
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("dims", [(2, 40, 4, 8, 2, 16), (1, 64, 2, 16, 1, 8),
                                  (2, 17, 6, 8, 3, 4)])
def test_ssd_kernel_sweep(dims):
    Bt, S, H, P, G, N = dims
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bt, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bv = jax.random.normal(ks[3], (Bt, S, G, N))
    Cv = jax.random.normal(ks[4], (Bt, S, G, N))
    y1, h1 = ops.ssd_scan_pallas(x, dt, A, Bv, Cv, chunk=16)
    y2, h2 = ssd_chunked(x, dt, A, Bv, Cv, chunk=16)
    np.testing.assert_allclose(y1, y2, atol=3e-4)
    np.testing.assert_allclose(h1, h2, atol=3e-4)


def test_ssd_intra_kernel_vs_ref():
    Q, P, N = 16, 8, 4
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (1, Q, 1, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, Q, 1)))
    dA = -jax.nn.softplus(jax.random.normal(ks[2], (1, Q, 1)))
    B = jax.random.normal(ks[3], (1, Q, 1, N))
    C = jax.random.normal(ks[4], (1, Q, 1, N))
    from repro.kernels.ssd_scan import ssd_intra_chunk
    y, st = ssd_intra_chunk(x, dt, dA, B, C)
    y_r, st_r = ref.ssd_intra_ref(x[0, :, 0], dt[0, :, 0], dA[0, :, 0],
                                  B[0, :, 0], C[0, :, 0])
    np.testing.assert_allclose(y[0, :, 0], y_r, atol=1e-5)
    np.testing.assert_allclose(st[0, 0], st_r, atol=1e-5)


@pytest.mark.parametrize("shape", [(7, 64), (2, 33, 128), (300, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel_sweep(shape, dtype):
    from repro.kernels.ops import rmsnorm_fused
    x = jax.random.normal(KEY, shape, dtype)
    scale = jax.random.normal(jax.random.PRNGKey(2), (shape[-1],), dtype) + 1.0
    got = rmsnorm_fused(x, scale)
    want = ref.rmsnorm_ref(x, scale)
    atol = 1e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_rmsnorm_kernel_matches_model_layer():
    from repro.kernels.ops import rmsnorm_fused
    from repro.models.layers import rmsnorm as model_rmsnorm
    x = jax.random.normal(KEY, (4, 10, 96))
    scale = jnp.ones((96,)) * 1.3
    np.testing.assert_allclose(rmsnorm_fused(x, scale),
                               model_rmsnorm(x, scale), atol=1e-5)


# --- golden coverage for the remaining kernel entry points (CPU interpret) --

@pytest.mark.parametrize("kind", ["l1", "l2", "box"])
def test_prox_step_tree_golden(kind):
    """The pytree wrapper applies the fused update leafwise == leafwise ref."""
    ks = jax.random.split(KEY, 4)
    params = {"w": jax.random.normal(ks[0], (33, 17)),
              "b": jax.random.normal(ks[1], (17,))}
    grads = {"w": jax.random.normal(ks[2], (33, 17)),
             "b": jax.random.normal(ks[3], (17,))}
    got = ops.prox_step_tree(params, grads, 0.07, kind=kind, lam=0.03)
    for leaf in ("w", "b"):
        want = ref.prox_step_ref(params[leaf], grads[leaf], jnp.float32(0.07),
                                 kind=kind, lam=0.03)
        np.testing.assert_allclose(got[leaf], want, atol=1e-6)


def test_ssd_kernel_with_initial_state_golden():
    """h0 carry-in: chunked kernel path == oracle, and chaining two halves
    through h0 == one full pass (the decode/streaming contract)."""
    Bt, S, H, P, G, N = 2, 32, 2, 8, 1, 4
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (Bt, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bv = jax.random.normal(ks[3], (Bt, S, G, N))
    Cv = jax.random.normal(ks[4], (Bt, S, G, N))
    h0 = jax.random.normal(ks[5], (Bt, H, P, N))
    y1, hf1 = ops.ssd_scan_pallas(x, dt, A, Bv, Cv, chunk=8, h0=h0)
    y2, hf2 = ssd_chunked(x, dt, A, Bv, Cv, chunk=8, h0=h0)
    np.testing.assert_allclose(y1, y2, atol=3e-4)
    np.testing.assert_allclose(hf1, hf2, atol=3e-4)
    # streaming: run halves chained via the carried state
    half = S // 2
    ya, ha = ops.ssd_scan_pallas(x[:, :half], dt[:, :half], A, Bv[:, :half],
                                 Cv[:, :half], chunk=8, h0=h0)
    yb, hb = ops.ssd_scan_pallas(x[:, half:], dt[:, half:], A, Bv[:, half:],
                                 Cv[:, half:], chunk=8, h0=ha)
    np.testing.assert_allclose(jnp.concatenate([ya, yb], axis=1), y1,
                               atol=3e-4)
    np.testing.assert_allclose(hb, hf1, atol=3e-4)


# ---- backend-aware interpret dispatch (repro.kernels.dispatch) ----------

def test_default_interpret_backend_aware(monkeypatch):
    """Compiled on tpu/gpu, interpreted everywhere else -- the pre-fix
    default (`backend != "tpu"`) wrongly interpreted on gpu."""
    from repro.kernels import dispatch
    monkeypatch.delenv(dispatch._ENV_VAR, raising=False)
    for backend, want in [("tpu", False), ("gpu", False), ("cpu", True)]:
        monkeypatch.setattr(jax, "default_backend", lambda b=backend: b)
        assert dispatch.default_interpret() is want


@pytest.fixture
def _fresh_interpret_guard():
    """Flipping REPRO_PALLAS_INTERPRET between default_interpret() calls is
    a guarded error in a real process; these parse tests legitimately vary
    it, so scrub the first-resolution record around each."""
    from repro.kernels import dispatch
    dispatch._reset_env_guard()
    yield
    dispatch._reset_env_guard()


@pytest.mark.parametrize("value,want", [("1", True), ("true", True),
                                        ("ON", True), ("0", False),
                                        ("no", False), ("False", False)])
def test_default_interpret_env_override(monkeypatch, _fresh_interpret_guard,
                                        value, want):
    from repro.kernels import dispatch
    monkeypatch.setenv(dispatch._ENV_VAR, value)
    assert dispatch.default_interpret() is want


def test_default_interpret_env_invalid(monkeypatch, _fresh_interpret_guard):
    from repro.kernels import dispatch
    monkeypatch.setenv(dispatch._ENV_VAR, "maybe")
    with pytest.raises(ValueError, match="REPRO_PALLAS_INTERPRET"):
        dispatch.default_interpret()


def test_resolve_interpret_explicit_wins(monkeypatch, _fresh_interpret_guard):
    from repro.kernels import dispatch
    monkeypatch.setenv(dispatch._ENV_VAR, "0")
    assert dispatch.resolve_interpret(True) is True
    assert dispatch.resolve_interpret(False) is False
    assert dispatch.resolve_interpret(None) is False


@pytest.mark.parametrize("n", [1, 127, 128, 1023, 1024, 1025, 4097])
def test_prox_step_pad_tail_edges(n):
    """1-D sizes straddling the LANES tiling: padded tail lanes must not
    leak into the result (explicit interpret=True -- the entry point jit
    caches on the static interpret key, so the default is tested above)."""
    x = jax.random.normal(KEY, (n,))
    g = jax.random.normal(jax.random.PRNGKey(5), (n,))
    got = ops.prox_step(x, g, 0.2, kind="l1", lam=0.03, interpret=True)
    want = ref.prox_step_ref(x, g, jnp.float32(0.2), kind="l1", lam=0.03)
    np.testing.assert_allclose(got, want, atol=1e-6)
    assert got.shape == (n,)


@pytest.mark.parametrize("gqa,window", [((8, 2), 16), ((4, 4), 9)])
def test_flash_gqa_sliding_window_golden(gqa, window):
    """GQA fold + sliding window against the naive model attention."""
    from repro.models.attention import attend
    H, KV = gqa
    B, S, d = 2, 40, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, KV, d))
    v = jax.random.normal(ks[2], (B, S, KV, d))
    pos = jnp.arange(S, dtype=jnp.int32)
    got = ops.flash_attention(q, k, v, pos, pos, causal=True, window=window,
                              scale=d ** -0.5)
    want = attend(q, k, v, pos, pos, causal=True, window=window,
                  scale=d ** -0.5, q_chunk=16, impl="naive")
    np.testing.assert_allclose(got, want, atol=2e-5)
