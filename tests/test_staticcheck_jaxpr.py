"""Tests for repro.staticcheck.jaxpr: the canonical-jaxpr comparator."""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.staticcheck import jaxpr as sj


def _trace(fn, *args):
    return jax.make_jaxpr(fn)(*args)


def test_fingerprint_stable_across_traces():
    def fn(x, y):
        return jnp.dot(x, y) + 1.0

    x = jnp.ones((3, 4))
    y = jnp.ones((4,))
    assert sj.fingerprint(_trace(fn, x, y)) == sj.fingerprint(_trace(fn, x, y))


def test_alpha_rename_invariance():
    # same program, different python variable/argument names
    def a(x, y):
        z = x * y
        return z + x

    def b(p, q):
        r = p * q
        return r + p

    v = jnp.ones((5,))
    assert sj.fingerprint(_trace(a, v, v)) == sj.fingerprint(_trace(b, v, v))


def test_detects_structural_change():
    v = jnp.ones((5,))
    add = _trace(lambda x, y: x + y, v, v)
    sub = _trace(lambda x, y: x - y, v, v)
    assert sj.fingerprint(add) != sj.fingerprint(sub)
    d = sj.diff(add, sub, "add", "sub")
    assert "add" in d and "sub" in d and d  # non-empty unified diff


def test_detects_nested_scan_body_change():
    xs = jnp.arange(8.0)

    def outer(body):
        def fn(xs):
            return lax.scan(body, 0.0, xs)
        return _trace(fn, xs)

    plus = outer(lambda c, x: (c + x, x))
    times = outer(lambda c, x: (c * x, x))
    assert sj.fingerprint(plus) != sj.fingerprint(times)


def test_diff_empty_and_assert_identical():
    v = jnp.ones((3,))
    a = _trace(lambda x: x * 2.0, v)
    b = _trace(lambda x: x * 2.0, v)
    assert sj.diff(a, b) == ""
    sj.assert_identical(a, b)
    c = _trace(lambda x: x * 3.0, v)
    with pytest.raises(AssertionError, match="canonical jaxprs differ"):
        sj.assert_identical(a, c)


def test_io_avals():
    a = _trace(lambda x, y: (x + y, x.sum()), jnp.ones((2, 3)), jnp.ones((2, 3)))
    ins, outs = sj.io_avals(a)
    assert len(ins) == 2 and len(outs) == 2
    assert all("2,3" in s for s in ins)


def test_literal_and_const_rendering_deterministic():
    big = jnp.arange(12.0).reshape(3, 4)

    def fn(x):
        return x @ big  # captures `big` as a const

    t1, t2 = _trace(fn, jnp.ones((2, 3))), _trace(fn, jnp.ones((2, 3)))
    text = sj.canonical_text(t1)
    assert sj.fingerprint(t1) == sj.fingerprint(t2)
    assert "0x" not in text.replace("0x~", "")  # no raw addresses leak
