"""Equivalence suite for the jitted federated event layer (PR 3).

Layers, each tied to the trusted heapq reference:

1. traces   -- ``federated_trace_scan`` is BITWISE-equal to
               ``simulate_federated(..., client_rounds=...)`` on the same
               pre-sampled rounds: event order (including simultaneous-upload
               ties, resolved by (time, seq) push order), stamps, staleness,
               aggregation pattern, f32 arrival times, dropout/rejoin chains.
2. wrapper  -- ``generate_federated_trace`` equals the reference and is
               invariant to the pop/attempt budget (bigger budgets extend the
               realization instead of resampling it).
3. sweeps   -- fused ``sweep_fedbuff``/``sweep_fedasync`` rows match solo
               ``run_fedbuff``/``run_fedasync`` over the same trace, and the
               ``reference=True`` escape hatch is bitwise the default path's
               event stream.
4. clipped  -- the ``StepsizeState.clipped`` horizon diagnostic surfaces in
               sweep result rows.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import Adaptive1, L1, make_logreg
from repro.core.engine import WorkerModel
from repro.core.stepsize import HingeWeight, PolyWeight, make_policy
from repro.federated.events import (ClientModel, ClientRounds, client_arrays,
                                    default_fed_steps, federated_trace_scan,
                                    generate_federated_trace,
                                    heterogeneous_clients,
                                    sample_client_rounds, simulate_federated)
from repro.federated.server import local_prox_sgd, run_fedbuff
from repro.sweep import make_grid, sweep_fedasync_problem, sweep_fedbuff_problem, sweep_piag_logreg

CLIENTS = {
    "hetero": heterogeneous_clients(6, seed=3, p_dropout=0.0),
    "hetero_dropout": heterogeneous_clients(6, seed=3, p_dropout=0.1,
                                            rejoin_after=2.0),
    "heavy_dropout": heterogeneous_clients(5, seed=1, p_dropout=0.35,
                                           rejoin_after=1.0),
    # deterministic timings: every completion collides -> pure tie-break test
    "ties": [ClientModel(compute=WorkerModel(mean=1.0, sigma=0.0),
                         upload=WorkerModel(mean=0.5, sigma=0.0))
             for _ in range(4)],
    # ties + dropout + rejoin landing exactly on round boundaries
    "ties_rejoin": [ClientModel(compute=WorkerModel(mean=1.0, sigma=0.0),
                                upload=WorkerModel(mean=1.0, sigma=0.0),
                                p_dropout=0.4, rejoin_after=2.0)
                    for _ in range(4)],
}


def _scan_trace(clients, n_uploads, buffer_size, seed, n_steps):
    rounds = sample_client_rounds(list(clients), n_steps, seed=seed)
    p, r, e = client_arrays(list(clients))
    out = federated_trace_scan(
        ClientRounds(jnp.asarray(rounds.drop_u), jnp.asarray(rounds.duration)),
        jnp.asarray(p), jnp.asarray(r), jnp.asarray(e), n_uploads,
        buffer_size=buffer_size, n_steps=n_steps)
    return rounds, out


# ------------------------------------------------------------ 1. traces ----

@pytest.mark.parametrize("model", sorted(CLIENTS))
@pytest.mark.parametrize("buffer_size", [1, 3])
def test_fed_scan_matches_heapq(model, buffer_size):
    clients = CLIENTS[model]
    K, S = 250, 1200
    rounds, out = _scan_trace(clients, K, buffer_size, seed=7, n_steps=S)
    assert int(out.n_uploads) == K
    assert not bool(out.exhausted)
    ref = simulate_federated(len(clients), K, clients,
                             buffer_size=buffer_size, seed=7,
                             client_rounds=rounds)
    for f in ("client", "read_at", "tau", "aggregate", "version",
              "local_steps"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(out, f)),
                                      err_msg=f"{model}/{f}")
    np.testing.assert_array_equal(ref.t_wall.astype(np.float32),
                                  np.asarray(out.t_wall),
                                  err_msg=f"{model}/t_wall")


def test_fed_scan_simultaneous_uploads_resolve_by_push_order():
    """All-deterministic clients collide on EVERY round boundary; both paths
    must order simultaneous uploads by (time, seq) -- round-robin in client
    order on the first wave."""
    clients = CLIENTS["ties"]
    K = 40
    rounds, out = _scan_trace(clients, K, 1, seed=0, n_steps=200)
    ref = simulate_federated(4, K, clients, seed=0, client_rounds=rounds)
    np.testing.assert_array_equal(ref.client, np.asarray(out.client))
    # first wave: all four uploads land at t=1.5 and pop in client order
    np.testing.assert_array_equal(np.asarray(out.client[:4]), np.arange(4))
    assert float(out.t_wall[0]) == float(out.t_wall[3])


def test_fed_scan_dropout_rejoin_exercised():
    """The heavy-dropout population must actually lose rounds (later final
    arrival than the same timings without dropout), while remaining
    bitwise-equal to the reference (already pinned above)."""
    flaky = CLIENTS["heavy_dropout"]
    steady = [ClientModel(compute=c.compute, upload=c.upload,
                          local_epochs=c.local_epochs, p_dropout=0.0)
              for c in flaky]
    K, S = 200, 1000
    _, out_flaky = _scan_trace(flaky, K, 1, seed=2, n_steps=S)
    _, out_steady = _scan_trace(steady, K, 1, seed=2, n_steps=S)
    assert float(out_flaky.t_wall[-1]) > float(out_steady.t_wall[-1])


def test_fed_scan_short_budget_reports_truncation():
    clients = CLIENTS["hetero"]
    _, out = _scan_trace(clients, 300, 1, seed=0, n_steps=100)
    assert int(out.n_uploads) < 300  # too few pops -> short, and flagged


# ----------------------------------------------------------- 2. wrapper ----

def test_generate_federated_trace_matches_reference_and_budget():
    clients = CLIENTS["hetero_dropout"]
    K = 300
    tr = generate_federated_trace(6, K, clients, seed=9)
    S = default_fed_steps(K)
    ref = simulate_federated(
        6, K, clients, seed=9,
        client_rounds=sample_client_rounds(clients, S, seed=9))
    for f in ("client", "read_at", "tau", "aggregate", "version"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(tr, f)), err_msg=f)
    # a 4x pop/attempt budget must reproduce the SAME realization
    tr_big = generate_federated_trace(6, K, clients, seed=9, n_steps=4 * S)
    for f in ("client", "tau", "version", "t_wall"):
        np.testing.assert_array_equal(np.asarray(getattr(tr, f)),
                                      np.asarray(getattr(tr_big, f)),
                                      err_msg=f)


def test_generate_federated_trace_autogrows_budget():
    """An undersized explicit budget is doubled until the trace completes."""
    clients = CLIENTS["heavy_dropout"]
    tr = generate_federated_trace(5, 200, clients, seed=4, n_steps=64)
    assert tr.n_events == 200
    assert np.all(np.diff(tr.t_wall) >= 0)


# ------------------------------------------------------------ 3. sweeps ----

@pytest.fixture(scope="module")
def problem():
    return make_logreg(240, 40, n_workers=4, seed=0)


def test_sweep_fedbuff_rows_match_solo(problem):
    """Acceptance: a fused ``sweep_fedbuff`` row equals a solo
    ``run_fedbuff`` of that cell's config over the same trace."""
    prox = L1(lam=problem.lam1)
    clients = heterogeneous_clients(4, seed=2, p_dropout=0.05)
    grid = make_grid(
        policies={"poly": PolyWeight(gamma_prime=1.0, a=0.5),
                  "hinge": HingeWeight(gamma_prime=1.0, a=2.0, b=2.0)},
        seeds=[0, 1],
        topologies={"edge": clients},
        n_events=120)
    eta, R = 0.4, 3
    res = sweep_fedbuff_problem(problem, grid, prox, eta=eta, buffer_size=R,
                                local_lr=0.5 / problem.L)
    assert res.objective.shape == (len(grid), 120)
    Aw, bw = problem.worker_slices()
    update = local_prox_sgd(
        lambda x, A, b: problem.worker_loss(x, A, b), prox, 0.5 / problem.L)
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    for i, cell in enumerate(grid.cells):
        trace = generate_federated_trace(4, 120, clients=list(cell.workers),
                                         buffer_size=R, seed=cell.seed)
        solo = run_fedbuff(update, x0, (Aw, bw), trace, cell.policy, eta=eta,
                           buffer_size=R, objective=problem.P)
        np.testing.assert_array_equal(np.asarray(solo.taus),
                                      np.asarray(res.taus[i]))
        np.testing.assert_array_equal(np.asarray(solo.versions),
                                      np.asarray(res.versions[i]))
        np.testing.assert_allclose(np.asarray(solo.weights),
                                   np.asarray(res.weights[i]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(solo.objective),
                                   np.asarray(res.objective[i]),
                                   rtol=1e-5, atol=1e-6)


def test_sweep_fedasync_reference_hatch_is_bitwise_twin(problem):
    prox = L1(lam=problem.lam1)
    grid = make_grid(
        policies={"hinge": HingeWeight(gamma_prime=0.6)},
        seeds=[0, 1, 2],
        topologies={"edge": heterogeneous_clients(4, seed=5, p_dropout=0.1)},
        n_events=100)
    fused = sweep_fedasync_problem(problem, grid, prox)
    ref = sweep_fedasync_problem(problem, grid, prox, reference=True)
    np.testing.assert_array_equal(np.asarray(fused.taus),
                                  np.asarray(ref.taus))
    np.testing.assert_array_equal(np.asarray(fused.versions),
                                  np.asarray(ref.versions))
    np.testing.assert_allclose(np.asarray(fused.objective),
                               np.asarray(ref.objective), rtol=1e-6,
                               atol=1e-7)


# ----------------------------------------------------------- 4. clipped ----

def test_clipped_counter_surfaces_in_sweep_rows(problem):
    """An undersized horizon (H - 1 < max delay) must be visible per cell
    via the ``clipped`` column instead of silently truncating window sums."""
    gp = 0.99 / problem.L
    prox = L1(lam=problem.lam1)
    grid = make_grid(
        policies={"a1": Adaptive1(gamma_prime=gp)},
        seeds=[0, 1],
        topologies={"u": [WorkerModel() for _ in range(4)]},
        n_events=150)
    tight = sweep_piag_logreg(problem, grid, prox, horizon=2)
    roomy = sweep_piag_logreg(problem, grid, prox, horizon=4096)
    assert tight.clipped.shape == (len(grid),)
    assert np.all(np.asarray(tight.clipped) > 0)   # delays exceed H - 1 = 1
    assert np.all(np.asarray(roomy.clipped) == 0)  # generous horizon: silent
    # count equals the number of events whose delay exceeded the cap
    taus = np.asarray(roomy.taus)
    np.testing.assert_array_equal(np.asarray(tight.clipped),
                                  (taus > 1).sum(axis=1))


def test_clipped_counter_in_federated_rows(problem):
    prox = L1(lam=problem.lam1)
    grid = make_grid(
        policies={"hinge": make_policy("hinge", 0.6)},
        seeds=[0],
        topologies={"edge": heterogeneous_clients(4, seed=5)},
        n_events=80)
    res = sweep_fedasync_problem(problem, grid, prox)
    assert res.clipped.shape == (1,)
    assert np.all(np.asarray(res.clipped) >= 0)
