"""Sharding planner unit tests on an abstract 16x16 production mesh
(no devices needed -- AbstractMesh carries only shape/axis names)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   param_shardings)

def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(sizes, names)            # jax >= 0.5
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))  # jax 0.4.x shape_tuple


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def sds(*shape, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct(shape, dtype)


def spec_of(shardings):
    return jax.tree_util.tree_map(lambda s: tuple(s.spec), shardings,
                                  is_leaf=lambda x: hasattr(x, "spec"))


def test_megatron_column_and_row_parallel():
    tree = {"layers": {"attn": {"wq": sds(60, 7168, 7168)},
                       "mlp": {"w1": sds(60, 7168, 20480),
                               "w2": sds(60, 20480, 7168)}}}
    sp = spec_of(param_shardings(tree, MESH))
    # w1 column-parallel (ff out on model), FSDP on d_model
    assert sp["layers"]["mlp"]["w1"] == (None, "data", "model")
    # w2 row-parallel (ff in on model)
    assert sp["layers"]["mlp"]["w2"] == (None, "model", "data")
    # layer-stack axis never sharded
    for leaf in jax.tree_util.tree_leaves(sp, is_leaf=lambda x: isinstance(x, tuple)):
        assert leaf[0] is None


def test_expert_parallel_when_divisible():
    tree = {"layers": {"moe": {"w1": sds(60, 160, 5120, 1536)}}}
    sp = spec_of(param_shardings(tree, MESH))
    assert sp["layers"]["moe"]["w1"][1] == "model"  # 160 experts / 16


def test_expert_fallback_when_not_divisible():
    tree = {"layers": {"moe": {"w1": sds(24, 60, 2048, 1408)}}}
    sp = spec_of(param_shardings(tree, MESH))
    assert sp["layers"]["moe"]["w1"][1] is None     # 60 % 16 != 0
    assert "model" in sp["layers"]["moe"]["w1"]     # falls back to a feature dim


def test_small_out_rule_replicates_row_parallel_small_projection():
    tree = {"layers": {"attn": {"w_dkv": sds(60, 5120, 576)}}}
    sp0 = spec_of(param_shardings(tree, MESH))
    assert sp0["layers"]["attn"]["w_dkv"][1] == "model"   # baseline: row-parallel
    sp1 = spec_of(param_shardings(tree, MESH, small_out_threshold=1024))
    assert "model" not in sp1["layers"]["attn"]["w_dkv"]  # replicated over model


def test_embedding_vocab_sharded():
    tree = {"embed": {"tok": sds(152064, 5120)}}
    sp = spec_of(param_shardings(tree, MESH))
    assert sp["embed"]["tok"] == ("model", "data")


def test_non_divisible_dims_replicated():
    tree = {"x": sds(7, 13)}
    sp = spec_of(param_shardings(tree, MESH))
    assert sp["x"] == (None, None)


def test_batch_sharding_multipod():
    tree = {"tokens": sds(256, 4096, dtype=jnp.int32)}
    sp = spec_of(batch_shardings(tree, MESH3, 256))
    assert sp["tokens"][0] == ("pod", "data")


def test_cache_context_parallel():
    tree = {"ckv": sds(60, 128, 32768, 512)}
    sp0 = spec_of(cache_shardings(tree, MESH, 128, 32768))
    assert sp0["ckv"][3] == "model"               # baseline: latent dim
    sp1 = spec_of(cache_shardings(tree, MESH, 128, 32768,
                                  context_parallel=True))
    assert sp1["ckv"][2] == "model"               # opt: sequence dim
    assert sp1["ckv"][1] == "data"                # batch on data either way


def test_cache_batch1_context_parallel_over_data():
    tree = {"k": sds(40, 1, 8192, 4, 128)}
    sp = spec_of(cache_shardings(tree, MESH, 1, 8192))
    assert sp["k"][2] == "data"                   # seq over data when B=1


def test_recipes_follow_measured_guidance():
    from repro.launch.recipes import recommended_knobs
    # token-input training: full bundle incl chunked CE for 256k vocab
    k = recommended_knobs("nemotron-4-15b", "train_4k")
    assert k["remat_chunk"] and k["shard_acts"] and k["ce_chunk"] == 512
    # small vocab: no ce_chunk
    assert "ce_chunk" not in recommended_knobs("zamba2-2.7b", "train_4k")
    # embedding-input training: remat only (H5 regression fix)
    k = recommended_knobs("qwen2-vl-72b", "train_4k")
    assert k == dict(remat_chunk=True)
    # decode: context-parallel cache everywhere
    assert recommended_knobs("deepseek-v2-236b", "decode_32k") == dict(cp_cache=True)
