"""PIAG and Async-BCD solvers: convergence, delay bookkeeping, runtimes."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (Adaptive1, Adaptive2, FixedStepSize, L1,
                        PIAGServer, SharedMemoryBCD, make_logreg,
                        run_bcd_logreg, run_piag_logreg,
                        simulate_parameter_server, simulate_shared_memory)


@pytest.fixture(scope="module")
def problem():
    return make_logreg(800, 100, n_workers=6, seed=0)


@pytest.fixture(scope="module")
def trace():
    return simulate_parameter_server(6, 1500, seed=1)


def test_trace_consistency(trace):
    # write-event delays: tau_k = k - read_at >= 0, tau_max >= tau
    assert np.all(trace.tau >= 0)
    assert np.all(trace.tau_max >= trace.tau)
    assert np.all(trace.read_at[1:] <= np.arange(1, trace.n_events) + 1)
    # every worker appears
    assert set(np.unique(trace.worker)) == set(range(6))


def test_piag_adaptive_converges(problem, trace):
    res = run_piag_logreg(problem, trace,
                          Adaptive1(gamma_prime=0.99 / problem.L),
                          L1(lam=problem.lam1))
    assert np.all(np.isfinite(res.objective))
    assert res.objective[-1] < res.objective[0] - 0.02
    # monotone-ish trend: final tenth below first tenth
    k = len(res.objective) // 10
    assert res.objective[-k:].mean() < res.objective[:k].mean()


def test_piag_adaptive_beats_fixed(problem, trace):
    """The paper's headline: same trace, adaptive reaches a lower objective
    (larger step-size integral, Prop. 1)."""
    tau_max = trace.max_delay()
    gp = 0.99 / problem.L
    res_a = run_piag_logreg(problem, trace, Adaptive1(gamma_prime=gp),
                            L1(lam=problem.lam1))
    res_f = run_piag_logreg(problem, trace,
                            FixedStepSize(gamma_prime=gp, tau_bound=tau_max),
                            L1(lam=problem.lam1))
    assert float(np.sum(res_a.gammas)) > float(np.sum(res_f.gammas))
    assert res_a.objective[-1] <= res_f.objective[-1] + 1e-6


def test_piag_gammas_respect_principle(problem, trace):
    from repro.core import check_principle
    gp = 0.99 / problem.L
    res = run_piag_logreg(problem, trace, Adaptive2(gamma_prime=gp),
                          L1(lam=problem.lam1))
    assert check_principle(np.asarray(res.gammas), np.asarray(res.taus), gp)


def test_bcd_converges(problem):
    trace = simulate_shared_memory(4, 2000, 10, seed=2)
    res = run_bcd_logreg(problem, trace,
                         Adaptive1(gamma_prime=0.99 / problem.block_smoothness(10)),
                         L1(lam=problem.lam1), m=10)
    assert np.all(np.isfinite(res.objective))
    assert res.objective[-1] < res.objective[0] - 0.02
    # every block eventually updated
    assert len(np.unique(np.asarray(res.blocks))) == 10


@pytest.mark.slow
def test_threaded_piag_runtime(problem):
    srv = PIAGServer(problem, Adaptive1(gamma_prime=0.99 / problem.L),
                     L1(lam=problem.lam1), n_workers=4, record_every=20)
    log = srv.run(400)
    assert log.objective[-1] < log.objective[0]
    assert max(log.taus) >= 1  # real asynchrony observed


@pytest.mark.slow
def test_threaded_bcd_runtime(problem):
    bcd = SharedMemoryBCD(problem,
                          Adaptive1(gamma_prime=0.99 / problem.block_smoothness(10)),
                          L1(lam=problem.lam1), n_workers=4, m_blocks=10,
                          record_every=20)
    log = bcd.run(400)
    assert log.objective[-1] < log.objective[0]


def test_piag_per_message_tau_beats_tau_max_under_persistent_straggler():
    """EXPERIMENTS.md §Perf follow-up: with one permanently slow worker,
    tau_max-coupled budgets throttle everyone; per-message tau recovers a
    far larger step-size integral without diverging."""
    from repro.core import WorkerModel
    from repro.core.piag import run_piag
    import jax.numpy as jnp
    prob = make_logreg(600, 80, n_workers=6, seed=0)
    workers = [WorkerModel(mean=25.0 if i == 0 else 1.0) for i in range(6)]
    trace = simulate_parameter_server(6, 1500, workers, seed=1)
    prox = L1(lam=prob.lam1)
    gp = 0.99 / prob.L
    Aw, bw = prob.worker_slices()
    x0 = jnp.zeros((prob.dim,), jnp.float32)
    loss = lambda x, A, b: prob.worker_loss(x, A, b)
    r_max = run_piag(loss, x0, (Aw, bw), trace, Adaptive1(gamma_prime=gp),
                     prox, objective=prob.P, use_tau_max=True)
    r_own = run_piag(loss, x0, (Aw, bw), trace, Adaptive1(gamma_prime=gp),
                     prox, objective=prob.P, use_tau_max=False)
    assert float(np.sum(r_own.gammas)) > 5.0 * float(np.sum(r_max.gammas))
    assert np.all(np.isfinite(r_own.objective))
    assert r_own.objective[-1] <= r_max.objective[-1] + 1e-6
