"""The telemetry subsystem's contract (`repro.telemetry`).

The load-bearing pin is **bitwise neutrality**: the in-scan accumulators
ride the solver carries as an extra, data-independent element, so every
original solver output with telemetry ON must be bitwise-equal to
telemetry OFF -- for all four solvers and all three backends.  On top of
that: the in-carry histogram against a numpy ``bincount`` reference
(adversarial delay streams included: all-zero, horizon-pinned, overflow
past the last bucket), exactness under decimated recording (the
``record_every == n_events`` edge), ``RunRecord`` well-formedness on the
64-cell fast grid, reset-scoped program-cache deltas, and the JSONL
ledger round-trip.
"""
import dataclasses
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import analysis, api
from repro.core import (Adaptive1, Adaptive2, FixedStepSize, L1,
                        SunDengFixed, make_logreg)
from repro.core.engine import WorkerModel, heterogeneous_workers
from repro.core.stepsize import HingeWeight, PolyWeight
from repro.federated.events import heterogeneous_clients
from repro.sweep import make_grid, standard_topologies
from repro.sweep.cache import clear_program_cache, program_cache_stats
from repro.telemetry import (COMPILE_EVENT_NAMES, RunRecord, TelemetryConfig,
                             append_record, cache_delta, drain_timings,
                             init_telemetry, observe, read_ledger,
                             record_timing, set_ledger_path,
                             spec_fingerprint, summarize_telemetry, timed,
                             warn_clip_pressure)

N_EVENTS = 100
N_EVENTS_FED = 80

SOLVER_KW = {"piag": {}, "bcd": {"m": 8}, "fedasync": {},
             "fedbuff": {"eta": 0.5, "buffer_size": 2}}


@pytest.fixture(scope="module")
def problem():
    return make_logreg(240, 40, n_workers=4, seed=0)


@pytest.fixture(scope="module")
def prox(problem):
    return L1(lam=problem.lam1)


@pytest.fixture(scope="module")
def worker_grid(problem):
    gp = 0.99 / problem.L
    return make_grid(
        policies={"a1": Adaptive1(gamma_prime=gp),
                  "fx": FixedStepSize(gamma_prime=gp, tau_bound=40)},
        seeds=[0, 1],
        topologies={"uniform": [WorkerModel() for _ in range(4)],
                    "hetero": heterogeneous_workers(4, seed=1)},
        n_events=N_EVENTS)


@pytest.fixture(scope="module")
def fed_grid():
    return make_grid(
        policies={"hinge": HingeWeight(gamma_prime=0.6),
                  "poly": PolyWeight(gamma_prime=0.6, a=0.5)},
        seeds=[0, 1],
        topologies={"edge": heterogeneous_clients(4, seed=2)},
        n_events=N_EVENTS_FED)


def _grid_for(solver, worker_grid, fed_grid):
    return fed_grid if solver in ("fedasync", "fedbuff") else worker_grid


def _run(solver, backend, problem, grid, prox, telemetry, **kw):
    return api.run_components(solver, backend, problem=problem, grid=grid,
                              prox=prox, horizon=4096, telemetry=telemetry,
                              telemetry_bins=64,
                              **{**SOLVER_KW[solver], **kw})


# -------------------------------------------------- bitwise neutrality ----

@pytest.mark.parametrize("backend", api.BACKENDS)
@pytest.mark.parametrize("solver", list(api.SOLVERS))
def test_telemetry_is_bitwise_neutral(solver, backend, problem, worker_grid,
                                      fed_grid, prox):
    """Telemetry ON must not perturb a single bit of any solver output,
    on any backend: the accumulator is carry-along state, never an input
    to the numerics."""
    grid = _grid_for(solver, worker_grid, fed_grid)
    off = _run(solver, backend, problem, grid, prox, telemetry=False)
    on = _run(solver, backend, problem, grid, prox, telemetry=True)
    assert off.raw.telemetry is None
    assert on.raw.telemetry is not None
    for f in off.raw._fields:
        if f == "telemetry":
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(off.raw, f)), np.asarray(getattr(on.raw, f)),
            err_msg=f"{solver}/{backend}/{f}")
    # the accumulator histogram is exact over EVERY event, and at stride 1
    # it must equal the bincount of the recorded delay rows
    tel = on.raw.telemetry
    hist = np.asarray(tel.hist).sum(axis=0)
    taus = np.asarray(on.raw.taus).reshape(-1)
    np.testing.assert_array_equal(
        hist, np.bincount(np.clip(taus, 0, 63), minlength=64))
    assert int(hist.sum()) == on.n_cells * on.n_events


def test_telemetry_neutral_under_decimation_and_full_stride_edge(
        problem, worker_grid, prox):
    """record_every == n_events (a single recorded row) is the harshest
    decimation: outputs stay bitwise-neutral, the histogram still counts
    every event, and the lone window absorbs every clip."""
    off = _run("piag", "batched", problem, worker_grid, prox,
               telemetry=False, record_every=N_EVENTS)
    on = _run("piag", "batched", problem, worker_grid, prox,
              telemetry=True, record_every=N_EVENTS)
    for f in ("x", "objective", "gammas", "taus", "clipped"):
        np.testing.assert_array_equal(
            np.asarray(getattr(off.raw, f)), np.asarray(getattr(on.raw, f)),
            err_msg=f)
    tel = on.raw.telemetry
    assert np.asarray(tel.window_clips).shape == (len(worker_grid), 1)
    assert int(np.asarray(tel.hist).sum()) == on.n_cells * N_EVENTS
    # stride-1 and full-stride accumulators agree: decimation drops rows,
    # never aggregate events
    on1 = _run("piag", "batched", problem, worker_grid, prox, telemetry=True)
    np.testing.assert_array_equal(np.asarray(tel.hist),
                                  np.asarray(on1.raw.telemetry.hist))


# ----------------------------------------- accumulator vs numpy oracle ----

def _scan_observe(taus, gammas, clips, bins):
    cfg = TelemetryConfig(delay_bins=bins)

    def step(state, ev):
        t, g, c = ev
        return observe(state, t, g, c), None

    state, _ = jax.lax.scan(
        step, init_telemetry(cfg),
        (jnp.asarray(taus, jnp.int32), jnp.asarray(gammas, jnp.float32),
         jnp.asarray(clips, jnp.int32)))
    return state


@pytest.mark.parametrize("name,taus", [
    ("all_zero", np.zeros(50, np.int64)),
    ("horizon_pinned", np.full(50, 7, np.int64)),     # tau == bins - 1
    ("overflow", np.arange(50) % 23),                 # most exceed last bin
    ("adversarial_mix", np.r_[np.zeros(10, np.int64), np.full(10, 1000),
                              np.arange(30) % 8]),
])
def test_histogram_matches_numpy_bincount_reference(name, taus):
    """In-carry bincount == numpy reference, overflow coarsened into the
    last bucket, never dropped."""
    bins = 8
    rng = np.random.default_rng(3)
    gammas = rng.uniform(0.01, 1.0, size=taus.shape).astype(np.float32)
    clips = (taus >= 100).astype(np.int64)
    state = _scan_observe(taus, gammas, clips, bins)
    expected = np.bincount(np.clip(taus, 0, bins - 1), minlength=bins)
    np.testing.assert_array_equal(np.asarray(state.hist), expected, name)
    assert int(state.count) == taus.size
    # a finalized single-cell view: window column == total clips
    summ = summarize_telemetry(_finalized(state, clips))
    assert summ["count"] == taus.size
    assert summ["tau"]["min"] == int(taus.min())
    assert summ["tau"]["max"] == int(taus.max())
    assert summ["tau"]["mean"] == pytest.approx(float(taus.mean()), rel=1e-5)
    assert summ["tau"]["std"] == pytest.approx(float(taus.std()), rel=1e-3,
                                               abs=1e-3)
    assert summ["gamma"]["min"] == pytest.approx(float(gammas.min()))
    assert summ["gamma"]["max"] == pytest.approx(float(gammas.max()))
    assert summ["window_clips"]["total"] == int(clips.sum())


def _finalized(state, clips):
    from repro.telemetry import finalize
    return finalize(state._replace(win_clip=jnp.zeros((), jnp.int32)),
                    jnp.asarray([int(np.sum(clips))], jnp.int32))


def test_summarize_merges_cell_moments_exactly():
    """Batched (multi-cell) summaries use the parallel Welford merge --
    the merged mean/std must equal the pooled-population numpy values,
    not a mean of per-cell means."""
    rng = np.random.default_rng(0)
    cells = [rng.integers(0, 20, size=n) for n in (10, 40, 200)]
    states = [_scan_observe(t, np.ones_like(t, np.float32),
                            np.zeros_like(t), 32) for t in cells]
    batched = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *[
        _finalized(s, np.zeros(1)) for s in states])
    pooled = np.concatenate(cells)
    summ = summarize_telemetry(batched)
    assert summ["count"] == pooled.size
    assert summ["tau"]["mean"] == pytest.approx(float(pooled.mean()),
                                                rel=1e-5)
    assert summ["tau"]["std"] == pytest.approx(float(pooled.std()), rel=1e-4)
    np.testing.assert_array_equal(
        summ["hist"], np.bincount(np.clip(pooled, 0, 31), minlength=32))


def test_telemetry_config_validates_bins():
    with pytest.raises(ValueError):
        TelemetryConfig(delay_bins=1)
    with pytest.raises(ValueError):
        api.ExecutionSpec(telemetry=True, telemetry_bins=1)


# --------------------------------------------- run ledger + RunRecord ----

@pytest.fixture(scope="module")
def grid64_run(problem, prox):
    """The benchmarks' 64-cell fast grid through the declarative runner
    with telemetry on, ledgered to a module-scoped file."""
    gp = 0.99 / problem.L
    grid = make_grid(
        policies={"adaptive1": Adaptive1(gamma_prime=gp),
                  "adaptive2": Adaptive2(gamma_prime=gp),
                  "fixed": FixedStepSize(gamma_prime=gp, tau_bound=40),
                  "sun_deng": SunDengFixed(gamma_prime=gp, tau_bound=40)},
        seeds=range(4),
        topologies=standard_topologies(4),
        n_events=120)
    assert len(grid) == 64
    res = api.run_components("piag", "batched", problem=problem, grid=grid,
                             prox=prox, telemetry=True)
    return grid, res


def test_run_record_well_formed_on_64_cell_grid(grid64_run):
    grid, res = grid64_run
    rec = res.telemetry
    assert isinstance(rec, RunRecord)
    assert rec.solver == "piag" and rec.backend == "batched"
    assert rec.n_cells == 64 and rec.n_events == 120
    assert rec.hist_source == "accumulator"
    assert sum(rec.delay_hist) == 64 * 120
    assert rec.elapsed_ms > 0
    assert rec.warm_ms >= 0 and rec.compile_ms >= 0
    assert rec.warm_ms <= rec.elapsed_ms + 1e-6
    assert rec.carry_bytes > 0
    assert rec.policies == ["adaptive1", "adaptive2", "fixed", "sun_deng"]
    assert set(rec.cache) == {"hits", "misses", "evictions", "size", "reset"}
    assert rec.tau_stats["max"] >= rec.tau_stats["min"] >= 0
    assert rec.clipped["cells"] == 64
    # the record is one JSON line, round-trippable
    d = json.loads(rec.to_json())
    rt = RunRecord.from_dict(d)
    assert rt.fingerprint == rec.fingerprint
    assert rt.delay_hist == rec.delay_hist


def test_results_surface_and_analysis_bridges(grid64_run):
    grid, res = grid64_run
    assert res.cache_stats == res.telemetry.cache
    assert "telemetry" not in res.extras  # not a solver-specific column
    dp = analysis.delay_profile(res)
    assert dp["source"] == "accumulator"
    assert dp["count"] == 64 * 120
    assert dp["tau"]["max"] == int(np.asarray(res.taus).max())
    cp = analysis.clip_pressure(res)
    assert cp["horizon"] == res.horizon
    assert 0.0 <= cp["clip_fraction"] <= 1.0


def test_recorded_fallback_when_telemetry_off(problem, worker_grid, prox):
    """Without the accumulators the ledger still gets a histogram --
    binned from the recorded rows and tagged as the 1/s sample it is."""
    res = _run("piag", "batched", problem, worker_grid, prox,
               telemetry=False)
    rec = res.telemetry
    assert rec.hist_source == "recorded"
    taus = np.asarray(res.raw.taus).reshape(-1)
    np.testing.assert_array_equal(
        rec.delay_hist, np.bincount(np.clip(taus, 0, 63), minlength=64))


def test_ledger_appends_one_json_line_per_run(problem, worker_grid, prox,
                                              tmp_path):
    path = tmp_path / "ledger.jsonl"
    set_ledger_path(path)
    try:
        _run("piag", "batched", problem, worker_grid, prox, telemetry=True)
        _run("bcd", "batched", problem, worker_grid, prox, telemetry=True)
    finally:
        set_ledger_path(None)
    recs = list(read_ledger(path))
    assert [r["solver"] for r in recs] == ["piag", "bcd"]
    for r in recs:
        rec = RunRecord.from_dict(r)
        assert sum(rec.delay_hist) == rec.n_cells * rec.n_events
    # no path configured -> append_record is a no-op
    assert append_record(RunRecord.from_dict(recs[0])) is False
    timeline = analysis.run_timeline(path)
    assert len(timeline) == 2
    assert timeline[0]["ts"] <= timeline[1]["ts"]


def test_spec_fingerprint_stable_and_value_keyed(problem, worker_grid, prox):
    s1 = api.component_spec("piag", "batched", problem=problem,
                            grid=worker_grid, prox=prox)
    s2 = api.component_spec("piag", "batched", problem=problem,
                            grid=worker_grid, prox=prox)
    assert spec_fingerprint(s1, worker_grid) == \
        spec_fingerprint(s2, worker_grid)
    assert len(spec_fingerprint(s1, worker_grid)) == 12


# --------------------------------------- cache stats + timing capture ----

def test_cache_delta_is_reset_scoped(problem, worker_grid, prox):
    """A repeated identical run hits the program cache (warm path); a
    clear_program_cache between snapshots flags the delta as reset and
    reports the post-clear counters verbatim."""
    clear_program_cache()
    first = _run("piag", "batched", problem, worker_grid, prox,
                 telemetry=True)
    again = _run("piag", "batched", problem, worker_grid, prox,
                 telemetry=True)
    assert first.cache_stats["misses"] >= 1
    assert again.cache_stats["hits"] >= 1
    assert again.cache_stats["misses"] == 0
    assert not again.cache_stats["reset"]
    # compile attribution follows the cache: warm run re-records nothing
    assert again.telemetry.compile_ms == 0.0

    before = program_cache_stats()
    clear_program_cache()
    warm = _run("piag", "batched", problem, worker_grid, prox,
                telemetry=True)
    delta = cache_delta(before, program_cache_stats())
    assert delta["reset"] is True
    assert warm.cache_stats["misses"] >= 1  # re-built after the clear


def test_cache_key_separates_telemetry_variants(problem, worker_grid, prox):
    """telemetry on/off and different bin counts are distinct programs --
    the config is part of the cache key, so a telemetry-on call can never
    be served a telemetry-off executable."""
    clear_program_cache()
    _run("piag", "batched", problem, worker_grid, prox, telemetry=False)
    on = _run("piag", "batched", problem, worker_grid, prox, telemetry=True)
    assert on.cache_stats["misses"] >= 1
    rebinned = api.run_components(
        "piag", "batched", problem=problem, grid=worker_grid, prox=prox,
        horizon=4096, telemetry=True, telemetry_bins=16)
    assert rebinned.cache_stats["misses"] >= 1
    assert len(rebinned.raw.telemetry.hist[0]) == 16


def test_timing_sink_records_and_drains():
    drain_timings()
    record_timing("unit_event", 1.5, key="k")
    with timed("unit_block", tag=7):
        pass
    events = drain_timings()
    assert [e["name"] for e in events] == ["unit_event", "unit_block"]
    assert events[0]["ms"] == 1.5 and events[0]["key"] == "k"
    assert events[1]["ms"] >= 0 and events[1]["tag"] == 7
    assert drain_timings() == []
    assert set(COMPILE_EVENT_NAMES) == {"program_build",
                                        "program_first_call"}


def test_run_drains_dispatch_timings_into_record(problem, worker_grid,
                                                 prox):
    clear_program_cache()
    res = _run("piag", "batched", problem, worker_grid, prox,
               telemetry=True)
    names = {t["name"] for t in res.telemetry.timings}
    assert "bucket_dispatch" in names
    assert "program_build" in names
    assert res.telemetry.compile_ms == pytest.approx(
        sum(t["ms"] for t in res.telemetry.timings
            if t["name"] in COMPILE_EVENT_NAMES))
    # the run drained its own events: nothing left in the sink
    assert all(t["name"] not in ("bucket_dispatch",)
               for t in drain_timings())


# ------------------------------------------------- clip-pressure path ----

def test_warn_clip_pressure_emits_runtime_warning():
    clean = {"cells": 4, "cells_clipped": 0, "events_clipped": 0,
             "max_events_clipped": 0}
    assert warn_clip_pressure(clean) is None
    hot = {"cells": 4, "cells_clipped": 2, "events_clipped": 9,
           "max_events_clipped": 6}
    with pytest.warns(RuntimeWarning, match="2/4 cells clipped"):
        msg = warn_clip_pressure(hot, horizon=8)
    assert "H=8" in msg


def test_clipped_summary_block_reaches_results(problem, prox):
    """A deliberately undersized horizon shows up in the RunRecord's
    clipped block and in analysis.clip_pressure."""
    gp = 0.99 / problem.L
    grid = make_grid(policies={"a1": Adaptive1(gamma_prime=gp)}, seeds=[0],
                     topologies={"hetero": heterogeneous_workers(4, seed=1)},
                     n_events=N_EVENTS)
    res = api.run_components("piag", "batched", problem=problem, grid=grid,
                             prox=prox, horizon=2, telemetry=True)
    rec = res.telemetry
    assert rec.clipped["events_clipped"] > 0
    cp = analysis.clip_pressure(res)
    assert cp["clip_fraction"] > 0
    with pytest.warns(RuntimeWarning):
        warn_clip_pressure(rec.clipped, horizon=res.horizon)
    # window_clips agrees with the carry counter, window by window in sum
    tel = res.raw.telemetry
    np.testing.assert_array_equal(
        np.asarray(tel.window_clips).sum(axis=-1),
        np.asarray(res.raw.clipped))
