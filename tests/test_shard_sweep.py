"""Ragged worker-count buckets + device-sharded sweeps (PR 3).

Masked-padding invariance: a cell padded into a wider bucket (service-time
rows + ``active_workers`` mask) must equal its exact-width run -- traces
bitwise, solver rows to the usual few-ulp envelope.  Sharded runners must
reproduce single-device rows exactly on any device count; the multi-device
assertions activate under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the CI multi-device lane).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Adaptive1, FixedStepSize, L1, make_logreg,
                        generate_trace, run_async_bcd, sample_blocks,
                        sample_service_times, trace_scan)
from repro.core.engine import WorkerModel, heterogeneous_workers
from repro.core.piag import piag_scan
from repro.core.stepsize import HingeWeight
from repro.federated.events import generate_federated_trace, heterogeneous_clients
from repro.federated.server import local_prox_sgd, run_fedasync
from repro.sweep import (cell_mesh, make_grid, next_pow2, round_robin_pad,
                         sharded_sweep_bcd, sharded_sweep_fedbuff,
                         sharded_sweep_piag_logreg,
                         standard_topology_factories, sweep_bcd_logreg,
                         sweep_fedasync_problem, sweep_fedbuff_problem,
                         sweep_piag_logreg)


@pytest.fixture(scope="module")
def problem():
    return make_logreg(240, 40, n_workers=8, seed=0)


def _ragged_grid(gp, n_events=150, widths=(4, 8)):
    return make_grid(
        policies={"a1": Adaptive1(gamma_prime=gp),
                  "fx": FixedStepSize(gamma_prime=gp, tau_bound=12)},
        seeds=[0, 1],
        topologies=standard_topology_factories(),
        n_events=n_events,
        n_workers=list(widths))


# -------------------------------------------------------- grid plumbing ----

def test_ragged_grid_structure():
    grid = _ragged_grid(0.5)
    assert grid.is_ragged
    assert grid.n_workers_max == 8
    with pytest.raises(ValueError):
        grid.n_workers  # ambiguous on a ragged grid
    buckets = grid.buckets()
    assert [b.width for b in buckets] == [4, 8]
    assert sum(len(b.grid) for b in buckets) == len(grid)
    assert all(c.n_workers == 4 for c in buckets[0].grid.cells)
    # every cell lands in exactly one bucket, in a stitchable order
    idx = np.sort(np.concatenate([b.index for b in buckets]))
    np.testing.assert_array_equal(idx, np.arange(len(grid)))


def test_bucket_widths_capped_at_widest_cell():
    """Regression: pow-2 padding must not outgrow the widest real topology
    (widths {4, 6} bucket to {4, 6}, not {4, 8} -- 8 would exceed the
    shared worker data and waste FLOPs on rows no cell uses)."""
    grid = make_grid(
        policies={"a1": Adaptive1(gamma_prime=0.5)},
        seeds=[0],
        topologies={"u": lambda n: [WorkerModel() for _ in range(n)]},
        n_events=20,
        n_workers=[4, 6])
    assert [b.width for b in grid.buckets()] == [4, 6]
    assert all(b.uniform for b in grid.buckets())
    # an explicit menu still wins
    assert [b.width for b in grid.buckets(bucket_widths=[8])] == [8]


def test_ragged_service_times_padded_with_inf():
    grid = _ragged_grid(0.5, n_events=50)
    T = grid.service_times(8)
    masks = grid.active_masks(8)
    assert T.shape == (len(grid), 8, 51)
    assert np.all(np.isinf(T[~masks]))
    assert np.all(np.isfinite(T[masks]))


def test_next_pow2_and_round_robin_pad():
    assert [next_pow2(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    idx = round_robin_pad(5, 4)
    assert idx.shape == (8,)
    np.testing.assert_array_equal(idx, [0, 1, 2, 3, 4, 0, 1, 2])


# ------------------------------------------------- masked trace padding ----

@pytest.mark.parametrize("pad_value", [np.inf, 1.0],
                         ids=["inf-pad", "finite-pad"])
def test_trace_scan_masked_padding_invariance(pad_value):
    """A padded+masked trace is bitwise the exact-width trace -- even when
    padding rows hold FINITE (race-winning) durations, proving the mask and
    not the pad value keeps them out."""
    workers = heterogeneous_workers(5, spread=3.0, seed=4)
    T = sample_service_times(workers, 201, seed=11)
    exact = trace_scan(jnp.asarray(T))
    T_pad = np.full((8, 201), pad_value, np.float32)
    T_pad[:5] = T
    active = np.arange(8) < 5
    padded = trace_scan(jnp.asarray(T_pad), active=jnp.asarray(active))
    for f in ("worker", "read_at", "tau", "tau_max", "t_wall"):
        np.testing.assert_array_equal(np.asarray(getattr(exact, f)),
                                      np.asarray(getattr(padded, f)),
                                      err_msg=f)


def test_trace_scan_all_active_mask_is_identity():
    workers = [WorkerModel(sigma=0.3) for _ in range(4)]
    T = jnp.asarray(sample_service_times(workers, 101, seed=3))
    a = trace_scan(T)
    b = trace_scan(T, active=jnp.ones((4,), bool))
    for f in ("worker", "tau", "tau_max", "t_wall"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


# ------------------------------------------- bucketed row == solo row ----

def _gamma_envelope(gp):
    return 32 * float(np.spacing(np.float32(gp)))


def test_ragged_sweep_piag_rows_match_exact_width_solo(problem):
    """Acceptance: a bucketed cell (4 active workers padded to width 8 would
    be in the 4-bucket here; both buckets checked) equals its exact-width
    solo run on the same data prefix."""
    gp = 0.99 / problem.L
    prox = L1(lam=problem.lam1)
    grid = _ragged_grid(gp)
    res = sweep_piag_logreg(problem, grid, prox)
    assert res.objective.shape == (len(grid), 150)
    Aw, bw = problem.worker_slices()
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    checked = set()
    for i, cell in enumerate(grid.cells):
        if cell.n_workers in checked and i % 5:
            continue
        checked.add(cell.n_workers)
        w = cell.n_workers
        T = sample_service_times(cell.workers, 151, seed=cell.seed)
        tr = trace_scan(jnp.asarray(T))
        solo = jax.jit(lambda ev: piag_scan(
            lambda x, A, b: problem.worker_loss(x, A, b), x0,
            (Aw[:w], bw[:w]), ev, cell.policy, prox,
            objective=problem.P))((tr.worker, tr.tau_max))
        np.testing.assert_array_equal(np.asarray(solo.taus),
                                      np.asarray(res.taus[i]))
        np.testing.assert_allclose(np.asarray(solo.gammas),
                                   np.asarray(res.gammas[i]),
                                   rtol=1e-6, atol=_gamma_envelope(gp))
        np.testing.assert_allclose(np.asarray(solo.objective),
                                   np.asarray(res.objective[i]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(solo.clipped),
                                      np.asarray(res.clipped[i]))


def test_ragged_sweep_bcd_rows_match_solo(problem):
    m = 8
    gp = 0.99 / problem.block_smoothness(m)
    prox = L1(lam=problem.lam1)
    grid = _ragged_grid(gp, n_events=120)
    res = sweep_bcd_logreg(problem, grid, prox, m=m)
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    for i in (0, len(grid) // 2, len(grid) - 1):
        cell = grid.cells[i]
        T = sample_service_times(cell.workers, 121, seed=cell.seed)
        trace = generate_trace(T, kind="shared_memory")
        blocks = sample_blocks(m, 120, seed=cell.seed)
        solo = run_async_bcd(problem.grad_f, problem.P, x0, m, trace, blocks,
                             cell.policy, prox)
        np.testing.assert_array_equal(np.asarray(solo.taus),
                                      np.asarray(res.taus[i]))
        np.testing.assert_array_equal(np.asarray(solo.blocks),
                                      np.asarray(res.blocks[i]))
        np.testing.assert_allclose(np.asarray(solo.objective),
                                   np.asarray(res.objective[i]),
                                   rtol=1e-5, atol=1e-6)


def test_ragged_sweep_fedasync_rows_match_exact_width_solo(problem):
    """Padded clients (mask) never start rounds: a ragged federated cell
    equals the solo run over its exact client population."""
    prox = L1(lam=problem.lam1)
    lr = 0.5 / problem.L
    grid = make_grid(
        policies={"hinge": HingeWeight(gamma_prime=0.6)},
        seeds=[0, 1],
        topologies={"edge": lambda n: heterogeneous_clients(n, seed=5,
                                                            p_dropout=0.1)},
        n_events=100,
        n_workers=[3, 8])
    res = sweep_fedasync_problem(problem, grid, prox, local_lr=lr)
    Aw, bw = problem.worker_slices()
    update = local_prox_sgd(
        lambda x, A, b: problem.worker_loss(x, A, b), prox, lr)
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    for i, cell in enumerate(grid.cells):
        w = cell.n_workers
        trace = generate_federated_trace(w, 100, clients=list(cell.workers),
                                         seed=cell.seed)
        solo = run_fedasync(update, x0, (Aw[:w], bw[:w]), trace, cell.policy,
                            objective=problem.P)
        np.testing.assert_array_equal(np.asarray(solo.taus),
                                      np.asarray(res.taus[i]))
        np.testing.assert_allclose(np.asarray(solo.objective),
                                   np.asarray(res.objective[i]),
                                   rtol=1e-5, atol=1e-6)


# -------------------------------------------------------------- sharded ----

def test_sharded_sweep_piag_rows_equal_single_device(problem):
    """Sharded vs single-device row equality; on one device this pins the
    mesh plumbing, under the CI multi-device lane (8 forced host devices)
    it exercises real sharding plus round-robin batch padding (12 cells
    pad to 16)."""
    gp = 0.99 / problem.L
    prox = L1(lam=problem.lam1)
    grid = make_grid(
        policies={"a1": Adaptive1(gamma_prime=gp),
                  "fx": FixedStepSize(gamma_prime=gp, tau_bound=12)},
        seeds=[0, 1, 2],
        topologies={"uniform": [WorkerModel() for _ in range(4)],
                    "hetero": heterogeneous_workers(4, seed=1)},
        n_events=120)
    assert len(grid) == 12
    batched = sweep_piag_logreg(problem, grid, prox)
    sharded = sharded_sweep_piag_logreg(problem, grid, prox)
    np.testing.assert_array_equal(np.asarray(batched.taus),
                                  np.asarray(sharded.taus))
    np.testing.assert_allclose(np.asarray(batched.objective),
                               np.asarray(sharded.objective),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(batched.x),
                               np.asarray(sharded.x), rtol=1e-6, atol=1e-7)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=N (CI multi-device lane)")
def test_multi_device_sharded_ragged_and_fed_rows(problem):
    """Under forced host devices: ragged sharded PIAG and sharded FedBuff
    reproduce the single-device rows across shard boundaries."""
    assert cell_mesh().devices.size >= 2
    gp = 0.99 / problem.L
    prox = L1(lam=problem.lam1)
    grid = _ragged_grid(gp, n_events=100)
    batched = sweep_piag_logreg(problem, grid, prox)
    sharded = sharded_sweep_piag_logreg(problem, grid, prox)
    np.testing.assert_array_equal(np.asarray(batched.taus),
                                  np.asarray(sharded.taus))
    np.testing.assert_allclose(np.asarray(batched.objective),
                               np.asarray(sharded.objective),
                               rtol=1e-6, atol=1e-7)

    gridf = make_grid(
        policies={"hinge": HingeWeight(gamma_prime=0.6)},
        seeds=[0, 1, 2],
        topologies={"edge": heterogeneous_clients(4, seed=5)},
        n_events=80)
    from repro.federated.server import _problem_pieces
    update, x0, data = _problem_pieces(problem, prox, None)
    batched_f = sweep_fedbuff_problem(problem, gridf, prox, eta=0.4,
                                      buffer_size=2)
    sharded_f = sharded_sweep_fedbuff(update, x0, data, gridf, eta=0.4,
                                      buffer_size=2, objective=problem.P)
    np.testing.assert_array_equal(np.asarray(batched_f.taus),
                                  np.asarray(sharded_f.taus))
    np.testing.assert_allclose(np.asarray(batched_f.objective),
                               np.asarray(sharded_f.objective),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs forced host devices")
def test_multi_device_sharded_bcd_rows(problem):
    m = 8
    gp = 0.99 / problem.block_smoothness(m)
    prox = L1(lam=problem.lam1)
    grid = _ragged_grid(gp, n_events=80)
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    batched = sweep_bcd_logreg(problem, grid, prox, m=m)
    sharded = sharded_sweep_bcd(problem.grad_f, problem.P, x0, m, grid, prox)
    np.testing.assert_array_equal(np.asarray(batched.blocks),
                                  np.asarray(sharded.blocks))
    np.testing.assert_allclose(np.asarray(batched.objective),
                               np.asarray(sharded.objective),
                               rtol=1e-6, atol=1e-7)
