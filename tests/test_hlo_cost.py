"""The while-aware HLO cost model: exact on scans where XLA's
cost_analysis undercounts loop bodies (counted once)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo, parse_hlo


N, L = 128, 7


def _scan_matmul():
    W = jnp.zeros((L, N, N))
    x = jnp.zeros((N, N))

    def f(x, W):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, W)
        return y
    return jax.jit(f).lower(x, W).compile()


def test_xla_cost_analysis_undercounts_scan():
    c = _scan_matmul()
    ca = c.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert ca["flops"] < 2 * N**3 * L * 0.5  # body counted once


def test_hlo_cost_exact_on_scan():
    c = _scan_matmul()
    cost = analyze_hlo(c.as_text())
    assert cost.flops == pytest.approx(2 * N**3 * L, rel=1e-6)


def test_hlo_cost_nested_scan():
    W = jnp.zeros((L, N, N))
    x = jnp.zeros((N, N))

    def f(x, W):
        def outer(c, w):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, W)
        return y
    c = jax.jit(f).lower(x, W).compile()
    cost = analyze_hlo(c.as_text())
    assert cost.flops == pytest.approx(2 * N**3 * L * 3, rel=1e-6)


def test_hlo_cost_fusion_dots_counted():
    x = jnp.zeros((N, N))

    def f(x):
        return jax.nn.relu(x @ x) * 2.0
    c = jax.jit(f).lower(x).compile()
    cost = analyze_hlo(c.as_text())
    assert cost.flops == pytest.approx(2 * N**3, rel=1e-6)


def test_parse_handles_tuple_shapes_with_index_comments():
    comps, entry, shapes = parse_hlo("""
ENTRY %main (a: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]{0}, /*index=2*/f32[8,2]{1,0}) while(%t), condition=%c, body=%b, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[4]{0} add(%a, %a)
}
""")
    assert entry == "main"
    ops = [i.op for i in comps["main"]]
    assert "while" in ops and "add" in ops
