"""Optimizers, schedules, data determinism, checkpoint roundtrip."""
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import Adaptive1, Adaptive2, L1
from repro.data import EmbedStream, TokenStream
from repro.optim import (AdamW, DelayAdaptiveOptimizer, Momentum, Sgd,
                         apply_updates, clip_by_global_norm, cosine_decay,
                         global_norm)


def quad_loss(p):
    return jnp.sum(jnp.square(p["w"] - 2.0))


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.zeros((4,))}
    opt = AdamW()
    st = opt.init(params)
    for _ in range(400):
        g = jax.grad(quad_loss)(params)
        upd, st = opt.update(g, st, params)
        params = apply_updates(params, upd, 0.05)
    assert float(quad_loss(params)) < 1e-4


def test_momentum_and_sgd():
    for opt in [Momentum(beta=0.9), Sgd()]:
        params = {"w": jnp.zeros((4,))}
        st = opt.init(params)
        for _ in range(300):
            g = jax.grad(quad_loss)(params)
            upd, st = opt.update(g, st, params)
            params = apply_updates(params, upd, 0.02)
        assert float(quad_loss(params)) < 1e-3


def test_delay_adaptive_optimizer_tracks_delays():
    params = {"w": jnp.ones((4,)) * 3}
    opt = DelayAdaptiveOptimizer(policy=Adaptive1(gamma_prime=0.4),
                                 base=Sgd(), prox=L1(lam=1e-3), n_workers=3)
    st = opt.init(params)
    taus = []
    for k in range(30):
        g = jax.grad(quad_loss)(params)
        params, st, gamma, tau = opt.update(params, g, st, jnp.int32(k % 3))
        taus.append(int(tau))
    # round-robin over 3 workers: steady-state delay = 2 write events
    assert taus[0] == 0 and set(taus[6:]) == {2}
    assert float(quad_loss(params)) < 0.5


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    c = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(c)) - 1.0) < 1e-5


def test_cosine_schedule_endpoints():
    fn = cosine_decay(1.0, 100, warmup_steps=10, final_frac=0.1)
    assert float(fn(jnp.int32(0))) == 0.0
    assert abs(float(fn(jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(fn(jnp.int32(100))) - 0.1) < 1e-6


def test_token_stream_deterministic_and_learnable():
    ts = TokenStream(vocab=64, batch=4, seq=32, seed=1)
    a, b = ts.batch_at(5), ts.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["targets"][:, :-1], a["tokens"][:, 1:])
    # bigram structure: next-token entropy must be far below uniform
    c = ts.batch_at(0)
    assert len(np.unique(np.asarray(c["tokens"]))) > 4


def test_embed_stream_mrope_positions():
    es = EmbedStream(d_model=32, vocab=16, batch=2, seq=80, mrope=True,
                     image_grid=(4, 4))
    b = es.batch_at(0)
    pos = np.asarray(b["positions"])
    assert pos.shape == (3, 2, 80)
    # image patches: t = 0, (h, w) in grid; text: all equal & increasing
    assert pos[0, 0, :16].max() == 0
    assert pos[1, 0, :16].max() == 3
    assert (pos[:, 0, 16:] == pos[0, 0, 16:]).all()


def test_checkpoint_roundtrip_nested():
    tree = {"p": {"w": jnp.arange(6.0).reshape(2, 3)},
            "s": [jnp.int32(3), jnp.ones((4,), jnp.bfloat16)]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.npz")
        save_checkpoint(path, tree, {"note": "hi", "step": 9})
        got, meta = load_checkpoint(path, tree)
        assert meta == {"note": "hi", "step": 9}
        np.testing.assert_allclose(got["p"]["w"], tree["p"]["w"])
        assert got["s"][1].dtype == jnp.bfloat16
