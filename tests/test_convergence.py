"""Convergence-rate order checks (Corollary 1)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Adaptive1, Adaptive2, Zero, run_piag,
                        simulate_parameter_server)


def _quad_problem(n_workers=4, d=20, seed=0):
    """f_i(x) = 0.5 (x - c_i)^T D (x - c_i): strongly convex (prox-PL),
    known L = max(D), sigma = min(D), P* computable in closed form."""
    rng = np.random.default_rng(seed)
    D = jnp.asarray(np.linspace(0.5, 2.0, d), jnp.float32)
    C = jnp.asarray(rng.normal(size=(n_workers, d)), jnp.float32)

    def worker_loss(x, c):
        return 0.5 * jnp.sum(D * (x - c) ** 2)

    c_bar = jnp.mean(C, axis=0)
    p_star = float(jnp.mean(jax.vmap(lambda c: worker_loss(c_bar, c))(C)))
    return worker_loss, C, D, c_bar, p_star


def test_piag_linear_rate_under_pl():
    """Theorem 2(3): under the PL condition the objective error decays
    geometrically in sum(gamma) -- check the log-error trend is linear and
    spans several orders of magnitude."""
    worker_loss, C, D, c_bar, p_star = _quad_problem()
    trace = simulate_parameter_server(4, 1200, seed=3)
    L = float(jnp.max(D))
    x0 = jnp.zeros((C.shape[1],), jnp.float32)

    def objective(x):
        return jnp.mean(jax.vmap(lambda c: worker_loss(x, c))(C))

    res = run_piag(worker_loss, x0, (C,), trace,
                   Adaptive1(gamma_prime=0.99 / L), Zero(),
                   objective=objective)
    err = np.asarray(res.objective) - p_star
    assert err[-1] > -1e-5  # P* is a true lower bound
    err = np.maximum(err, 1e-12)
    assert err[-1] < 1e-6 * err[0]  # many orders of magnitude
    # geometric decay: log-error vs cumulative step-size is ~affine until
    # the float32 noise floor (convergence is exact in f32 on this problem)
    csum = np.cumsum(np.asarray(res.gammas))
    floor = np.argmax(err <= 1e-6 * err[0])  # first index at/below 1e-6x
    floor = floor if floor > 0 else len(err) - 1
    k = floor // 2
    slope1 = (np.log(err[k]) - np.log(err[0])) / (csum[k] - csum[0])
    slope2 = (np.log(err[floor]) - np.log(err[k])) / (csum[floor] - csum[k])
    assert slope1 < 0 and slope2 < 0
    assert 0.3 < slope2 / slope1 < 3.0  # same order => linear, not sublinear


def test_piag_sublinear_rate_convex():
    """Theorem 2(2): error <= C / sum(gamma) for convex problems -- check
    err_k * csum_k stays bounded (O(1/k) order)."""
    worker_loss, C, D, c_bar, p_star = _quad_problem(seed=1)
    trace = simulate_parameter_server(4, 800, seed=4)
    L = float(jnp.max(D))
    x0 = jnp.zeros((C.shape[1],), jnp.float32)

    def objective(x):
        return jnp.mean(jax.vmap(lambda c: worker_loss(x, c))(C))

    res = run_piag(worker_loss, x0, (C,), trace,
                   Adaptive2(gamma_prime=0.99 / L), Zero(),
                   objective=objective)
    err = np.maximum(np.asarray(res.objective) - p_star, 1e-12)
    csum = np.cumsum(np.asarray(res.gammas))
    prod = err * csum
    # the bound C = P(x0)-P* + ||x0-x*||^2/(2 a0): check boundedness vs t=10
    assert prod[100:].max() <= prod[10] * 5.0


def test_theorem2_nonconvex_bound_constant():
    """Theorem 2(1): sum_k gamma_{k-1} ||grad f(x_k) + xi_k||^2
    <= 2(h^2-h+1)(P(x_0)-P*)/(1-h).  Checked with the exact constant on a
    PIAG run (prox-gradient mapping residual as the subgradient witness)."""
    from repro.core import Adaptive1, L1, make_logreg, run_piag_logreg, \
        simulate_parameter_server
    h = 0.9
    prob = make_logreg(600, 80, n_workers=5, seed=2)
    trace = simulate_parameter_server(5, 1500, seed=6)
    gp = h / prob.L
    res = run_piag_logreg(prob, trace, Adaptive1(gamma_prime=gp),
                          L1(lam=prob.lam1))
    # ||grad f(x_k) + xi_k|| equals the recorded prox-gradient residual
    lhs = float(np.sum(np.asarray(res.gammas) *
                       np.asarray(res.opt_residual) ** 2))
    p0 = float(prob.P(jnp.zeros((prob.dim,), jnp.float32)))
    p_star_ub = float(np.min(np.asarray(res.objective)))  # P* <= min seen
    rhs = 2 * (h * h - h + 1) * (p0 - p_star_ub) / (1 - h)
    assert lhs <= rhs * 1.01, (lhs, rhs)
