"""Beyond-paper demo: PIAG that needs NEITHER the delay bound NOR the
Lipschitz constant (the paper's §5 future work, made concrete).

We start with a step-size budget 1000x too optimistic; the on-line secant
curvature estimator (||dg||/||dx|| over each worker's consecutive gradients)
self-corrects within a few events and the run lands on the oracle-L
adaptive policy's objective.

    PYTHONPATH=src python examples/piag_lipschitz.py
"""
import numpy as np

from repro.core import (Adaptive1, L1, make_logreg, run_piag_lipschitz,
                        run_piag_logreg, simulate_parameter_server)


def main() -> None:
    prob = make_logreg(1500, 200, n_workers=8, seed=0)
    trace = simulate_parameter_server(8, 3000, seed=2)
    prox = L1(lam=prob.lam1)
    print(f"true L = {prob.L:.3e} (we will NOT tell the algorithm)")

    res = run_piag_lipschitz(prob, trace, prox, gamma0=1000.0)
    L_est = np.asarray(res.opt_residual)
    print(f"gamma0 = 1000.0 ({1000.0 * prob.L / 0.9:.0f}x the safe budget)")
    print(f"L_est after 10 events: {L_est[9]:.3e}; final: {L_est[-1]:.3e}")
    print(f"objective: {float(res.objective[0]):.4f} -> "
          f"{float(res.objective[-1]):.4f}")

    orc = run_piag_logreg(prob, trace, Adaptive1(gamma_prime=0.99 / prob.L),
                          prox)
    print(f"oracle-L Adaptive 1 final: {float(orc.objective[-1]):.4f}")


if __name__ == "__main__":
    main()
