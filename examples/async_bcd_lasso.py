"""Async-BCD with REAL threads on shared memory (paper §4.2 setting).

Eight worker threads hammer a shared iterate without read locks
(inconsistent reads, Eq. 6); the write-side critical section measures the
write-event delay and picks the delay-adaptive step-size (Algorithm 2).
Compares against the fixed step-sizes of [Sun'17] and [Davis'16].

    PYTHONPATH=src python examples/async_bcd_lasso.py
"""
import numpy as np

from repro.core import (Adaptive1, Adaptive2, DavisFixed, L1, SharedMemoryBCD,
                        SunDengFixed, make_logreg)

N_WORKERS = 8
M_BLOCKS = 20
EVENTS = 1500


def main() -> None:
    prob = make_logreg(n_samples=2000, dim=400, n_workers=N_WORKERS,
                       sparse_like=False, lam1=1e-3, lam2=1e-4, seed=0)
    Lhat = prob.block_smoothness(M_BLOCKS)   # Assumption 1 (block-wise)
    print(f"lasso-logistic: dim={prob.dim}, block Lhat={Lhat:.4f}, "
          f"{M_BLOCKS} blocks, {N_WORKERS} threads")
    gp = 0.99 / Lhat

    # a first adaptive run measures the delays this machine actually produces
    runs = {}
    probe = SharedMemoryBCD(prob, Adaptive1(gamma_prime=gp), L1(lam=prob.lam1),
                            n_workers=N_WORKERS, m_blocks=M_BLOCKS,
                            record_every=5)
    log = probe.run(EVENTS)
    tau_max = max(log.taus)
    runs["Adaptive 1"] = log
    print(f"measured delays: max={tau_max}, "
          f"frac<=5={np.mean(np.array(log.taus) <= 5):.0%}")

    ratio = 2.0 * prob.L / (Lhat * np.sqrt(M_BLOCKS))
    for name, pol in {
        "Adaptive 2": Adaptive2(gamma_prime=gp),
        "Fixed (Sun'17)": SunDengFixed(gamma_prime=gp, tau_bound=tau_max),
        "Fixed (Davis'16)": DavisFixed(gamma_prime=gp, tau_bound=tau_max,
                                       ratio=float(ratio)),
    }.items():
        bcd = SharedMemoryBCD(prob, pol, L1(lam=prob.lam1),
                              n_workers=N_WORKERS, m_blocks=M_BLOCKS,
                              record_every=5)
        runs[name] = bcd.run(EVENTS)

    print(f"\n{'policy':18s} {'P(x_0)':>8s} {'P(x_K)':>8s} {'wall(s)':>8s}")
    for name, lg in runs.items():
        print(f"{name:18s} {lg.objective[0]:8.4f} {lg.objective[-1]:8.4f} "
              f"{lg.wall[-1]:8.2f}")


if __name__ == "__main__":
    main()
