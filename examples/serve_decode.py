"""Batched serving example: prefill + KV-cache decode on a small model,
including a sliding-window ring-cache long-context decode and a VLM-style
(M-RoPE, embedding-input) prefill using the frontend stub.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.data import EmbedStream
from repro.launch.serve import generate
from repro.launch.train import PRESETS
from repro.models import decode_step, init_params, make_cache, prefill
from repro.models.config import ModelConfig


def text_serving() -> None:
    cfg = PRESETS["25m"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab,
                                 dtype=jnp.int32)
    out, stats = generate(cfg, params, prompts, gen=16, temperature=0.8)
    print(f"[text] generated {out.shape[0]}x{out.shape[1] - 32} tokens, "
          f"{stats['tok_per_s']:.1f} tok/s")


def long_context_ring_decode() -> None:
    """Sliding-window decode: the cache stays O(window), not O(position)."""
    cfg = PRESETS["25m"].replace(sliding_window=None, name="lm-ring")
    params = init_params(cfg, jax.random.PRNGKey(0))
    W = 64
    cache = make_cache(cfg, 2, W, ring=True)
    step = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos,
                                                    window=W, ring=True))
    tok = jnp.zeros((2, 1), jnp.int32)
    t0 = time.perf_counter()
    for pos in range(0, 512):  # positions far beyond the cache size
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    print(f"[ring ] decoded 512 positions through a {W}-slot ring cache "
          f"({512 * 2 / (time.perf_counter() - t0):.0f} tok/s)")


def vlm_prefill_decode() -> None:
    """VLM backbone: patch embeddings + M-RoPE grids from the stub."""
    cfg = ModelConfig(
        name="vlm-demo", family="vlm", embed_inputs=True, rope="mrope",
        mrope_sections=(8, 4, 4), n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, head_dim=32, d_ff=512, vocab=2048, q_chunk=64)
    params = init_params(cfg, jax.random.PRNGKey(2))
    es = EmbedStream(d_model=cfg.d_model, vocab=cfg.vocab, batch=2, seq=80,
                     mrope=True, image_grid=(6, 6))
    batch = es.batch_at(0)
    logits, cache = jax.jit(lambda p, b: prefill(p, cfg, b))(
        params, {k: batch[k] for k in ("embeds", "positions")})
    # continue with text decode through the token table
    full = make_cache(cfg, 2, 96)
    full = jax.tree_util.tree_map(
        lambda buf, c: jax.lax.dynamic_update_slice_in_dim(
            buf, c.astype(buf.dtype), 0, axis=2)
        if buf.ndim == c.ndim and buf.shape != c.shape else c.astype(buf.dtype),
        full, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for pos in range(80, 88):
        logits, full = decode_step(params, cfg, full, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    print(f"[vlm  ] prefilled 36 image patches + 44 text embeds, decoded 8 "
          f"text tokens; last token ids {tok[:, 0].tolist()}")


if __name__ == "__main__":
    text_serving()
    long_context_ring_decode()
    vlm_prefill_decode()
