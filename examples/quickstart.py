"""Quickstart: delay-adaptive PIAG on l1-regularized logistic regression.

Reproduces the paper's core result in ~30 seconds on CPU: on the SAME
asynchronous event trace, the delay-adaptive step-sizes (Eqs. 13-14) converge
substantially faster than the best known fixed step-size, because they spend
the full step-size budget gamma' whenever the system happens to be fast.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (Adaptive1, Adaptive2, L1, SunDengFixed, make_logreg,
                        run_piag_logreg, simulate_parameter_server)


def main() -> None:
    # synthetic rcv1-like problem (offline container), 10 workers as in §4.1
    prob = make_logreg(n_samples=2000, dim=400, n_workers=10,
                       sparse_like=True, lam1=1e-5, lam2=1e-4, seed=0)
    print(f"logistic regression: {prob.A.shape[0]} samples, dim {prob.dim}, "
          f"L={prob.L:.3f}")

    # one shared event trace from heterogeneous workers with stragglers
    trace = simulate_parameter_server(10, 3000, seed=1)
    print(f"simulated {trace.n_events} write events, max delay "
          f"{trace.max_delay()} (measured on-line, never assumed)")

    gamma_prime = 0.99 / prob.L
    prox = L1(lam=prob.lam1)
    policies = {
        "Adaptive 1 (Eq. 13)": Adaptive1(gamma_prime=gamma_prime, alpha=0.9),
        "Adaptive 2 (Eq. 14)": Adaptive2(gamma_prime=gamma_prime),
        "Fixed (Sun/Deng)": SunDengFixed(gamma_prime=gamma_prime,
                                         tau_bound=trace.max_delay()),
    }

    results = {}
    for name, pol in policies.items():
        res = run_piag_logreg(prob, trace, pol, prox)
        results[name] = np.asarray(res.objective)
        print(f"{name:22s} P(x_0)={results[name][0]:.4f} -> "
              f"P(x_K)={results[name][-1]:.4f}  "
              f"sum(gamma)={np.sum(res.gammas):.1f}")

    target = results["Fixed (Sun/Deng)"][-1]
    for name in list(policies)[:2]:
        hit = int(np.argmax(results[name] <= target))
        print(f"{name} reaches the fixed policy's final objective after "
              f"{hit}/{trace.n_events} events "
              f"({hit / trace.n_events:.0%} of the budget)")


if __name__ == "__main__":
    main()
