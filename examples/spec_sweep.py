"""One declarative spec, three backends.

Builds a single ``repro.api.ExperimentSpec`` for a fast PIAG policy grid
(the Fig. 2/3 shape at smoke-test scale) and runs it on every backend:

* ``solo``    -- one jitted run per cell (the pre-sweep reference path);
* ``batched`` -- the whole grid as one vmapped XLA program;
* ``sharded`` -- the batched program with the cell axis partitioned over
                 every device (forced host devices work too:
                 ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

The redesign's contract is that the backend is an execution detail: delays
are identical across the three, objectives agree to float tolerance, and
the per-policy story (``repro.analysis``) is the same table each time.
This file doubles as a ``--spec`` payload for the CLI::

    PYTHONPATH=src python -m repro.launch.sweep --spec examples/spec_sweep.py

    PYTHONPATH=src python examples/spec_sweep.py          # all 3 backends
"""
import numpy as np

from repro import analysis, api

# the fast grid: 2 policies x 2 seeds x 2 regimes, 4 workers, 150 events
SPEC = api.ExperimentSpec(
    problem=api.ProblemSpec(kind="logreg",
                            params=dict(n_samples=240, dim=40, seed=0)),
    solver=api.SolverSpec(name="piag", horizon=4096),
    topology=api.TopologySpec(kind="standard", names=("uniform", "hetero2"),
                              n_workers=(4,)),
    policies=api.PolicyGridSpec(names=("adaptive1", "fixed"), seeds=(0, 1)),
    n_events=150)


def main() -> None:
    results = {}
    for backend in api.BACKENDS:
        res = api.run(SPEC.replace(execution=api.ExecutionSpec(backend=backend)))
        results[backend] = res
        print(f"[{backend:>7}] {len(res)} cells x {res.n_events} events in "
              f"{res.elapsed_s:.2f}s (tau_bar={res.tau_bar})")
        for pn, s in analysis.summarize(res).items():
            print(f"          {pn:<10} mean P_final={s.mean_final:.5f} "
                  f"min={s.min_final:.5f} clipped={s.clipped_events}")

    # the backend is an execution detail: same delays, same objectives
    base = results["batched"]
    for backend in ("solo", "sharded"):
        other = results[backend]
        assert np.array_equal(np.asarray(base.taus), np.asarray(other.taus)), \
            f"{backend}: taus diverged from batched"
        np.testing.assert_allclose(np.asarray(base.objective),
                                   np.asarray(other.objective),
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=f"{backend} vs batched")
    print("OK: solo / batched / sharded agree "
          "(taus identical, objectives within float tolerance)")


if __name__ == "__main__":
    main()
