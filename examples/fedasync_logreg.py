"""Delay-adaptive FedAsync on l1-regularized logistic regression.

The server-side analogue of the paper's result: on the SAME federated event
trace (heterogeneous straggler clients, dropouts), a staleness-ADAPTIVE
mixing weight alpha * s(tau_k) driven by the measured per-upload delay
converges far faster than a constant weight tuned to the worst-case
staleness bound -- because it spends the full mixing budget whenever the
arriving model happens to be fresh.

    PYTHONPATH=src python examples/fedasync_logreg.py
"""
import numpy as np

from repro.core import L1, make_logreg, make_policy, solve_centralized
from repro.federated import (heterogeneous_clients, run_fedasync_problem,
                             simulate_federated)


def main() -> None:
    prob = make_logreg(n_samples=500, dim=50, n_workers=8, seed=0)
    prox = L1(lam=prob.lam1)
    _, objs = solve_centralized(prob, prox, iters=3000)
    p_star = float(objs[-1])
    gap0 = float(prob.P(np.zeros(prob.dim, np.float32))) - p_star
    print(f"logreg: {prob.A.shape[0]} samples over 8 clients, "
          f"centralized P* = {p_star:.5f}")

    # one shared trace: heterogeneous clients with stragglers and dropouts
    clients = heterogeneous_clients(8, spread=4.0, seed=1, p_straggle=0.05,
                                    p_dropout=0.02)
    trace = simulate_federated(8, 3000, clients, seed=1)
    tau_max = trace.max_delay()
    print(f"{trace.n_events} uploads, staleness p50/p90/max = "
          f"{int(np.percentile(trace.tau, 50))}/"
          f"{int(np.percentile(trace.tau, 90))}/{tau_max} "
          f"(measured on-line, never assumed)")

    alpha = 0.4
    policies = {
        "hinge (adaptive)": make_policy("hinge", alpha, a=0.5, b=16.0),
        "poly (adaptive)": make_policy("poly", alpha, a=0.3),
        "fixed tau-bound": make_policy("constant", alpha / (tau_max + 1)),
    }

    target = 0.2 * gap0
    for name, pol in policies.items():
        res = run_fedasync_problem(prob, trace, pol, prox,
                                   local_lr=0.5 / prob.L)
        sub = np.asarray(res.objective) - p_star
        hit = int(np.argmax(sub <= target)) if (sub <= target).any() else -1
        reached = f"{hit} uploads" if hit >= 0 else "never"
        print(f"{name:18s} final P-P* = {sub[-1]:.5f}  "
              f"reaches 20% gap after {reached}")


if __name__ == "__main__":
    main()
