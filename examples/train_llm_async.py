"""End-to-end driver: asynchronously train a ~100M-parameter LM with
delay-adaptive step-sizes for a few hundred steps (deliverable b).

Four simulated heterogeneous workers (one straggles 8x, 5% of the time)
feed a parameter server with REAL stale gradients; every write event applies
the arriving gradient with the delay-adaptive AdamW step (principle (8)).
Compares adaptive1 against the fixed worst-case policy on identical traces.

Runtime note: ~100M params on this CPU container takes a few seconds/step;
use --preset 25m --steps 100 for a quick pass, or the default below for the
full run.

    PYTHONPATH=src python examples/train_llm_async.py --steps 300
"""
import argparse
import json
import os

from repro.launch.train import PRESETS, run_training


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="100m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--compare-fixed", action="store_true",
                    help="also run the fixed worst-case-delay policy")
    ap.add_argument("--out", default="experiments/train_llm_async")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    os.makedirs(args.out, exist_ok=True)

    print("=== delay-adaptive (Adaptive 1) ===")
    log_a = run_training(cfg, steps=args.steps, batch=args.batch,
                         seq=args.seq, policy_name="adaptive1", lr=3e-3,
                         n_workers=args.workers, seed=0,
                         out_dir=os.path.join(args.out, "adaptive1"))

    summary = {"adaptive1_final": log_a[-1]["loss"],
               "adaptive1_first": log_a[0]["loss"]}
    if args.compare_fixed:
        print("=== fixed worst-case policy ===")
        log_f = run_training(cfg, steps=args.steps, batch=args.batch,
                             seq=args.seq, policy_name="fixed", lr=3e-3,
                             n_workers=args.workers, seed=0,
                             out_dir=os.path.join(args.out, "fixed"))
        summary["fixed_final"] = log_f[-1]["loss"]
        print(f"final loss: adaptive={log_a[-1]['loss']:.4f} "
              f"fixed={log_f[-1]['loss']:.4f}")
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    print("summary:", summary)


if __name__ == "__main__":
    main()
